//! The [`TunedPlan`] artifact: a seeded, fully deterministic JSON record
//! of one tuning run — what was searched, what won, what it should cost —
//! that `sparkv train --plan plan.json` replays through the ordinary
//! config keys (the `Scheduler`/`BucketSchedule`/`Executor` seams are
//! untouched, so a plan run is bit-identical to the same config written
//! by hand).

use super::calibrate::Calibration;
use super::oracle::CostOracle;
use super::space::{Candidate, SearchSpace, TuneScenario};
use super::strategy::SearchStrategy;
use crate::buckets::apportion_k;
use crate::config::{RawConfig, TrainConfig};
use crate::util::json::Json;

/// The seed `sparkv tune` uses when none is given (and the golden plan
/// pins). Any fixed seed ⇒ a byte-identical plan; this one is just the
/// default identity of "the default tuning run".
pub const DEFAULT_TUNE_SEED: u64 = 7;

/// Artifact schema version (bump on breaking JSON layout changes).
pub const PLAN_VERSION: usize = 1;

/// One leaderboard row: candidate identity, its predicted epoch time,
/// and the fidelity (virtual steps) the prediction covered — successive
/// halving retains eliminated candidates at their last (reduced) rung, so
/// rows are only comparable at equal `steps`. When measured promotion
/// ran, the promoted rows also carry the measured step wall-clock that
/// decided their order (rows are best-first by *measured* time among the
/// promoted, then by predicted time — so `epoch_s` alone need not be
/// ascending on a measured plan).
#[derive(Debug, Clone, PartialEq)]
pub struct LeaderboardEntry {
    pub name: String,
    pub epoch_s: f64,
    pub steps: usize,
    /// Mean measured seconds/step of the promotion probe (measured
    /// halving only).
    pub measured_step_s: Option<f64>,
}

/// The tuned-plan artifact. Everything needed to (a) replay the winning
/// configuration (`chosen` + the scenario's base density), (b) audit the
/// search (seed, strategy, evaluation count, leaderboard), and (c) check
/// the paper-trail invariants (per-bucket budgets, predicted-vs-baseline
/// times) without re-running anything.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedPlan {
    pub version: usize,
    /// The search seed. Serialized as a JSON number, so seeds must stay
    /// below 2⁵³ to round-trip exactly (the CLI enforces this; library
    /// callers passing larger seeds lose the low bits on save/load).
    pub seed: u64,
    /// The strategy's identity string (e.g. `grid`,
    /// `halving:eta=2,rungs=3`).
    pub strategy: String,
    /// Scenario identity: netsim model name + cluster shape + densities.
    pub model: String,
    pub params: u64,
    pub nodes: usize,
    pub gpus: usize,
    pub k_ratio: f64,
    pub steps_per_epoch: usize,
    pub layer_buckets: usize,
    /// The winning candidate.
    pub chosen: Candidate,
    pub predicted_epoch_s: f64,
    pub predicted_mean_iter_s: f64,
    /// Predicted epoch time of [`Candidate::baseline`] (the default
    /// config) under the same oracle — `chosen` is never worse.
    pub baseline_epoch_s: f64,
    pub speedup_vs_baseline: f64,
    /// The chosen candidate's per-bucket budgets at its schedule's base k
    /// ([`TuneScenario::base_k_for`]) over the simulated bucket
    /// partition: `Σ = min(k, d)`, `k_b ≤ d_b`, and each bucket respects
    /// the `bytes:N` budget (locked by the determinism proptest and the
    /// golden).
    pub bucket_ks: Vec<usize>,
    /// Oracle evaluations the search spent.
    pub evaluated: usize,
    /// Top candidates, best first (≤ 8 rows).
    pub leaderboard: Vec<LeaderboardEntry>,
    /// The measured calibration the oracle ran under, when one was fitted.
    pub calibration: Option<Calibration>,
}

/// Rows kept in the plan's leaderboard.
const LEADERBOARD_ROWS: usize = 8;

/// Run a search and assemble the plan. The baseline guard makes the
/// acceptance invariant structural: if the strategy's best candidate is
/// worse than the default config (possible with an aggressively
/// subsampled cohort), the plan falls back to the baseline — a tuned
/// plan's predicted epoch time is never above the default's. One
/// deliberate exception: a winner picked by *measured* promotion is kept
/// even when the simulator disagrees (measurement outranks the model —
/// discarding it would defeat the measured leg exactly where it
/// matters); such a plan reports its honest sim prediction, which may
/// sit above the baseline's.
pub fn tune(
    scenario: &TuneScenario,
    space: &SearchSpace,
    strategy: &mut dyn SearchStrategy,
    seed: u64,
    calibration: Option<&Calibration>,
) -> TunedPlan {
    let oracle = CostOracle::new(scenario, calibration);
    let result = strategy.search(space, &oracle, seed);
    let baseline = Candidate::baseline();
    let baseline_cost = oracle.predict(&baseline);
    let (chosen, chosen_cost) = match result.ranked.first() {
        Some(best) => {
            // Re-predict at full fidelity first (a strategy may have
            // ranked its winner at a reduced one); the baseline guard
            // must compare like with like, or a cheap low-fidelity score
            // could smuggle a worse-than-default candidate past it.
            let cost = if best.cost.steps == scenario.steps_per_epoch {
                best.cost.clone()
            } else {
                oracle.predict(&best.candidate)
            };
            // A measured winner bypasses the guard: its rank came from a
            // real training run, which outranks the simulation.
            if best.measured_step_s.is_some() || cost.epoch_s <= baseline_cost.epoch_s {
                (best.candidate.clone(), cost)
            } else {
                (baseline.clone(), baseline_cost.clone())
            }
        }
        None => (baseline.clone(), baseline_cost.clone()),
    };

    // Per-bucket budgets at the *chosen* schedule's base k (a `const:K`
    // winner overrides the scenario density — the artifact must record
    // the budgets the plan actually implies).
    let sizes = scenario.sim_bucket_sizes(chosen.buckets);
    let bucket_ks = apportion_k(&sizes, scenario.base_k_for(&chosen.k_schedule));

    let leaderboard = result
        .ranked
        .iter()
        .take(LEADERBOARD_ROWS)
        .map(|s| LeaderboardEntry {
            name: s.candidate.name(),
            epoch_s: s.cost.epoch_s,
            steps: s.cost.steps,
            measured_step_s: s.measured_step_s,
        })
        .collect();

    TunedPlan {
        version: PLAN_VERSION,
        seed,
        strategy: strategy.name(),
        model: scenario.model.name.to_string(),
        params: scenario.model.params,
        nodes: scenario.topo.nodes,
        gpus: scenario.topo.gpus_per_node,
        k_ratio: scenario.k_ratio,
        steps_per_epoch: scenario.steps_per_epoch,
        layer_buckets: scenario.layer_buckets,
        predicted_epoch_s: chosen_cost.epoch_s,
        predicted_mean_iter_s: chosen_cost.mean_iter_s,
        baseline_epoch_s: baseline_cost.epoch_s,
        speedup_vs_baseline: baseline_cost.epoch_s / chosen_cost.epoch_s,
        chosen,
        bucket_ks,
        evaluated: result.evaluated,
        leaderboard,
        calibration: calibration.cloned(),
    }
}

impl TunedPlan {
    pub fn to_json(&self) -> Json {
        let mut scenario = Json::obj();
        scenario
            .set("model", Json::from(self.model.as_str()))
            .set("params", Json::from(self.params as f64))
            .set("nodes", Json::from(self.nodes))
            .set("gpus", Json::from(self.gpus))
            .set("k_ratio", Json::from(self.k_ratio))
            .set("steps_per_epoch", Json::from(self.steps_per_epoch))
            .set("layer_buckets", Json::from(self.layer_buckets));
        let mut o = Json::obj();
        o.set("version", Json::from(self.version))
            .set("seed", Json::from(self.seed as f64))
            .set("strategy", Json::from(self.strategy.as_str()))
            .set("scenario", scenario)
            .set("chosen", self.chosen.to_json())
            .set("predicted_epoch_s", Json::from(self.predicted_epoch_s))
            .set(
                "predicted_mean_iter_s",
                Json::from(self.predicted_mean_iter_s),
            )
            .set("baseline_epoch_s", Json::from(self.baseline_epoch_s))
            .set("speedup_vs_baseline", Json::from(self.speedup_vs_baseline))
            .set(
                "bucket_ks",
                Json::Arr(self.bucket_ks.iter().map(|&k| Json::from(k)).collect()),
            )
            .set("evaluated", Json::from(self.evaluated))
            .set(
                "leaderboard",
                Json::Arr(
                    self.leaderboard
                        .iter()
                        .map(|e| {
                            let mut row = Json::obj();
                            row.set("name", Json::from(e.name.as_str()))
                                .set("epoch_s", Json::from(e.epoch_s))
                                .set("steps", Json::from(e.steps))
                                .set(
                                    "measured_step_s",
                                    e.measured_step_s.map_or(Json::Null, Json::from),
                                );
                            row
                        })
                        .collect(),
                ),
            )
            .set(
                "calibration",
                match &self.calibration {
                    Some(c) => c.to_json(),
                    None => Json::Null,
                },
            );
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TunedPlan> {
        let num = |node: &Json, key: &str| -> anyhow::Result<f64> {
            node.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("plan: missing numeric field '{key}'"))
        };
        let version = num(j, "version")? as usize;
        anyhow::ensure!(
            version == PLAN_VERSION,
            "plan version {version} unsupported (this build reads version {PLAN_VERSION})"
        );
        let scen = j
            .get("scenario")
            .ok_or_else(|| anyhow::anyhow!("plan: missing 'scenario'"))?;
        let chosen = Candidate::from_json(
            j.get("chosen").ok_or_else(|| anyhow::anyhow!("plan: missing 'chosen'"))?,
        )?;
        let leaderboard = j
            .get("leaderboard")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|row| -> anyhow::Result<LeaderboardEntry> {
                Ok(LeaderboardEntry {
                    name: row
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("plan: leaderboard row missing 'name'"))?
                        .to_string(),
                    epoch_s: num(row, "epoch_s")?,
                    steps: num(row, "steps")? as usize,
                    measured_step_s: row.get("measured_step_s").and_then(Json::as_f64),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let calibration = match j.get("calibration") {
            None | Some(Json::Null) => None,
            Some(c) => Some(Calibration::from_json(c)?),
        };
        Ok(TunedPlan {
            version,
            seed: num(j, "seed")? as u64,
            strategy: j
                .get("strategy")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("plan: missing 'strategy'"))?
                .to_string(),
            model: scen
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("plan: scenario missing 'model'"))?
                .to_string(),
            params: num(scen, "params")? as u64,
            nodes: num(scen, "nodes")? as usize,
            gpus: num(scen, "gpus")? as usize,
            k_ratio: num(scen, "k_ratio")?,
            steps_per_epoch: num(scen, "steps_per_epoch")? as usize,
            layer_buckets: num(scen, "layer_buckets")? as usize,
            chosen,
            predicted_epoch_s: num(j, "predicted_epoch_s")?,
            predicted_mean_iter_s: num(j, "predicted_mean_iter_s")?,
            baseline_epoch_s: num(j, "baseline_epoch_s")?,
            speedup_vs_baseline: num(j, "speedup_vs_baseline")?,
            bucket_ks: j
                .get("bucket_ks")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("plan: non-numeric bucket_ks entry"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            evaluated: num(j, "evaluated")? as usize,
            leaderboard,
            calibration,
        })
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing plan {path}: {e}"))
    }

    pub fn load(path: &str) -> anyhow::Result<TunedPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading plan {path}: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("plan {path}: {e}"))?)
    }

    /// Map the plan onto `[train]` config keys (the replay path of
    /// `sparkv train --plan`): every searched knob plus the
    /// scenario's base density *and* epoch length — a warmup-style
    /// schedule converts `epochs=E` through `steps_per_epoch`, so the
    /// replayed density trace matches the one the plan was scored on.
    /// A `tree-sparse` winner also sets `global_topk = true` (the tree is
    /// a gTop-k wire schedule; `validate` rejects it otherwise), exactly
    /// as [`Candidate::apply`] does on the typed path. Replay goes
    /// through the ordinary string-parse path, so a plan is exactly
    /// equivalent to writing the same keys in a config file.
    pub fn apply(&self, raw: &mut RawConfig) -> anyhow::Result<()> {
        raw.set(&format!("train.op={}", self.chosen.op.name()))?;
        raw.set(&format!("train.k_schedule={}", self.chosen.k_schedule.name()))?;
        raw.set(&format!("train.buckets={}", self.chosen.buckets.name()))?;
        raw.set(&format!(
            "train.bucket_apportion={}",
            self.chosen.bucket_apportion.name()
        ))?;
        raw.set(&format!("train.parallelism={}", self.chosen.parallelism.name()))?;
        raw.set(&format!("train.exchange={}", self.chosen.exchange.name()))?;
        raw.set(&format!("train.select={}", self.chosen.select.name()))?;
        raw.set(&format!("train.wire={}", self.chosen.wire.name()))?;
        if self.chosen.exchange.is_tree() {
            raw.set("train.global_topk=true")?;
        }
        raw.set(&format!("train.k_ratio={}", self.k_ratio))?;
        raw.set(&format!("train.steps_per_epoch={}", self.steps_per_epoch))?;
        Ok(())
    }

    /// Apply the plan directly to a typed config (library-side replay;
    /// same keys as [`TunedPlan::apply`]).
    pub fn to_train_config(&self, mut base: TrainConfig) -> TrainConfig {
        self.chosen.apply(&mut base);
        base.k_ratio = self.k_ratio;
        base.steps_per_epoch = self.steps_per_epoch;
        base
    }

    /// One-line human summary for CLI/bench output.
    pub fn summary(&self) -> String {
        format!(
            "{} → predicted {:.4} s/epoch ({:.4} s/iter), baseline {:.4} s/epoch, {:.2}× ({} candidates, strategy {})",
            self.chosen.name(),
            self.predicted_epoch_s,
            self.predicted_mean_iter_s,
            self.baseline_epoch_s,
            self.speedup_vs_baseline,
            self.evaluated,
            self.strategy
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::strategy::ExhaustiveGrid;
    use crate::compress::OpKind;
    use crate::config::{Buckets, Exchange, Parallelism, Select};

    fn quick_scenario() -> TuneScenario {
        let mut s = TuneScenario::default_16gpu();
        s.steps_per_epoch = 6;
        s
    }

    #[test]
    fn tune_beats_baseline_and_round_trips_json() {
        let scen = quick_scenario();
        let plan = tune(
            &scen,
            &SearchSpace::default_space(),
            &mut ExhaustiveGrid,
            DEFAULT_TUNE_SEED,
            None,
        );
        assert!(plan.predicted_epoch_s <= plan.baseline_epoch_s);
        assert!(plan.speedup_vs_baseline >= 1.0);
        assert_eq!(plan.version, PLAN_VERSION);
        assert_eq!(plan.strategy, "grid");
        assert!(!plan.leaderboard.is_empty());
        assert!(plan.leaderboard.len() <= 8);
        // Σ bucket_ks == min(k, d) at the chosen schedule's base k
        // (apportion_k guarantee surfaced in the artifact).
        let k = scen.base_k_for(&plan.chosen.k_schedule);
        assert_eq!(plan.bucket_ks.iter().sum::<usize>(), k.min(scen.model.params as usize));
        // Byte-exact JSON round trip through the parser.
        let text = plan.to_json().to_string();
        let back = TunedPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn plan_applies_to_raw_and_typed_configs_identically() {
        let scen = quick_scenario();
        let plan = tune(
            &scen,
            &SearchSpace::default_space(),
            &mut ExhaustiveGrid,
            3,
            None,
        );
        // String-keyed replay (the CLI path)…
        let mut raw = RawConfig::default();
        plan.apply(&mut raw).unwrap();
        let from_raw = TrainConfig::from_raw(&raw).unwrap();
        // …and the typed replay agree on every searched knob.
        let typed = plan.to_train_config(TrainConfig::default());
        assert_eq!(from_raw.op, typed.op);
        assert_eq!(from_raw.k_schedule, typed.k_schedule);
        assert_eq!(from_raw.buckets, typed.buckets);
        assert_eq!(from_raw.bucket_apportion, typed.bucket_apportion);
        assert_eq!(from_raw.parallelism, typed.parallelism);
        assert_eq!(from_raw.exchange, typed.exchange);
        assert_eq!(from_raw.select, typed.select);
        assert_eq!(from_raw.wire, typed.wire);
        assert_eq!(from_raw.global_topk, typed.global_topk);
        assert_eq!(from_raw.k_ratio, typed.k_ratio);
        assert_eq!(typed.k_ratio, scen.k_ratio);
        // Epoch length replays too (warmup grammars convert through it).
        assert_eq!(from_raw.steps_per_epoch, typed.steps_per_epoch);
        assert_eq!(typed.steps_per_epoch, scen.steps_per_epoch);
        from_raw.validate().unwrap();
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let scen = quick_scenario();
        let plan = tune(
            &scen,
            &SearchSpace::smoke_space(),
            &mut ExhaustiveGrid,
            11,
            None,
        );
        let dir = std::env::temp_dir().join("sparkv_plan_test");
        let path = dir.join("plan.json");
        plan.save(path.to_str().unwrap()).unwrap();
        let loaded = TunedPlan::load(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded, plan);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn baseline_guard_kicks_in_for_a_worse_only_space() {
        // A space of candidates strictly worse than the default config:
        // RedSync-style Trimmed, serial, monolithic. The plan must fall
        // back to the baseline rather than ship a slowdown.
        let scen = quick_scenario();
        let space = SearchSpace {
            ops: vec![OpKind::Trimmed],
            k_schedules: vec![crate::schedule::KSchedule::Const(None)],
            buckets: vec![Buckets::None],
            apportions: vec![crate::config::BucketApportion::Size],
            parallelisms: vec![Parallelism::Serial],
            exchanges: vec![Exchange::DenseRing],
            selects: vec![Select::Exact],
            wires: vec![crate::tensor::wire::WireCodec::Raw],
        };
        let plan = tune(&scen, &space, &mut ExhaustiveGrid, 5, None);
        assert_eq!(plan.chosen, Candidate::baseline());
        assert_eq!(plan.predicted_epoch_s.to_bits(), plan.baseline_epoch_s.to_bits());
        assert_eq!(plan.speedup_vs_baseline, 1.0);
    }

    #[test]
    fn measured_winner_bypasses_the_baseline_guard() {
        // A space that is strictly sim-worse than the baseline, but whose
        // winner was picked by a *measured* probe: the plan must keep the
        // measured winner (measurement outranks the model), report its
        // honest sim prediction (> baseline), and serialize the measured
        // wall-clock in the leaderboard.
        let scen = quick_scenario();
        let space = SearchSpace {
            ops: vec![OpKind::Trimmed],
            k_schedules: vec![crate::schedule::KSchedule::Const(None)],
            buckets: vec![Buckets::None],
            apportions: vec![crate::config::BucketApportion::Size],
            parallelisms: vec![Parallelism::Serial],
            exchanges: vec![Exchange::DenseRing],
            selects: vec![Select::Exact],
            wires: vec![crate::tensor::wire::WireCodec::Raw],
        };
        let mut halving = crate::autotune::strategy::SuccessiveHalving {
            promote: 1,
            measure: Some(Box::new(|_: &Candidate| Ok(0.001))),
            ..crate::autotune::strategy::SuccessiveHalving::default()
        };
        let plan = tune(&scen, &space, &mut halving, 5, None);
        assert_eq!(plan.chosen.op, OpKind::Trimmed);
        assert!(plan.predicted_epoch_s > plan.baseline_epoch_s);
        assert!(plan.speedup_vs_baseline < 1.0);
        assert_eq!(plan.leaderboard[0].measured_step_s, Some(0.001));
        // Some(measured) round-trips through the JSON artifact.
        let back =
            TunedPlan::from_json(&Json::parse(&plan.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn tuned_plan_switches_exchange_with_the_bandwidth_regime() {
        // The acceptance demonstration at plan level: give the search both
        // wirings of the same gTop-k candidate and let the cluster shape
        // decide. On the paper's 16-GPU / 10 GbE testbed the tree's
        // 2·⌈log₂16⌉ = 8 rounds beat the all-gather ring's P − 1 = 15, so
        // the tuned plan flips to `tree-sparse`; on one 4-GPU node
        // (4 rounds vs 3) the ring keeps winning and the plan stays on
        // `dense-ring`. Numerics are identical either way, so this is a
        // pure wire-schedule decision.
        let space = SearchSpace {
            ops: vec![OpKind::TopK],
            k_schedules: vec![crate::schedule::KSchedule::Const(None)],
            buckets: vec![Buckets::None],
            apportions: vec![crate::config::BucketApportion::Size],
            parallelisms: vec![Parallelism::Serial],
            exchanges: vec![Exchange::DenseRing, Exchange::TreeSparse],
            selects: vec![Select::Exact],
            wires: vec![crate::tensor::wire::WireCodec::Raw],
        };

        let wide = quick_scenario(); // 4 nodes × 4 GPUs over 10 GbE
        let plan_wide = tune(&wide, &space, &mut ExhaustiveGrid, 5, None);
        assert_eq!(plan_wide.chosen.exchange, Exchange::TreeSparse);
        assert!(plan_wide.chosen.name().ends_with("|tree-sparse"));
        assert!(plan_wide.predicted_epoch_s < plan_wide.baseline_epoch_s);

        let mut narrow = quick_scenario();
        narrow.topo = crate::netsim::Topology::new(
            1,
            4,
            crate::netsim::LinkSpec::pcie3_x16(),
            crate::netsim::LinkSpec::ethernet_10g(),
        );
        let plan_narrow = tune(&narrow, &space, &mut ExhaustiveGrid, 5, None);
        assert_eq!(plan_narrow.chosen.exchange, Exchange::DenseRing);

        // A tree winner replays through the raw-config path with the
        // gTop-k flag it needs to validate.
        let mut raw = RawConfig::default();
        plan_wide.apply(&mut raw).unwrap();
        let cfg = TrainConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.exchange, Exchange::TreeSparse);
        assert!(cfg.global_topk);
        cfg.validate().unwrap();
    }

    #[test]
    fn seeded_plans_are_byte_identical() {
        let scen = quick_scenario();
        let mk = |seed| {
            tune(&scen, &SearchSpace::default_space(), &mut ExhaustiveGrid, seed, None)
                .to_json()
                .to_string()
        };
        assert_eq!(mk(7), mk(7));
    }
}
