//! Closed-loop autotuning: search the compression-plan space with the
//! netsim cost model in the loop, calibrate the model against measured
//! probe steps, and emit a deterministic, replayable [`TunedPlan`].
//!
//! The paper's central systems insight is that the best sparsification
//! configuration is workload-dependent: the right (operator, density,
//! bucketing, runtime) point moves with the model, the cluster shape, and
//! the phase of training (Adaptive Top-K, Ruan et al. 2022; the
//! supercomputing-scale study of Yoon & Oh 2022). Every ingredient for a
//! search loop already exists in this crate — the [`crate::schedule`]
//! plan engine, the bucketed pipeline, the three worker runtimes, and the
//! calibrated [`crate::netsim`] cost model with its per-runtime launch
//! overhead — but nothing closed the loop. This module does:
//!
//! ```text
//!                 ┌──────────────────────────────────────────────┐
//!                 │                 sparkv tune                  │
//!                 └──────────────────────────────────────────────┘
//!   ┌───────────┐   candidates    ┌──────────────┐   predicted
//!   │ Search    │ ──────────────▶ │ CostOracle   │   epoch time
//!   │ Space     │                 │ (netsim +    │ ─────────────┐
//!   │ op × k-   │                 │  runtime     │              ▼
//!   │ schedule ×│                 │  overhead)   │      ┌──────────────┐
//!   │ buckets × │                 └──────▲───────┘      │ Search       │
//!   │ apportion │                        │ constants    │ Strategy     │
//!   │ × runtime │                 ┌──────┴───────┐      │ grid/greedy/ │
//!   └───────────┘                 │ Calibrator   │      │ halving      │
//!                                 │ (measured    │      └──────┬───────┘
//!        measured probe steps ───▶│ probe steps) │             │ winner
//!        (StepRecord wall/launch) └──────────────┘             ▼
//!                                                      ┌──────────────┐
//!   sparkv train --plan plan.json  ◀───────────────────│ TunedPlan    │
//!   (replays through the existing                      │ (seeded,     │
//!    Scheduler/BucketSchedule/                         │  bit-exact   │
//!    Executor seams, untouched)                        │  JSON)       │
//!                                                      └──────────────┘
//! ```
//!
//! ## The three layers
//!
//! * [`space`] — the configuration space: a [`Candidate`] is one point of
//!   {[`OpKind`](crate::compress::OpKind) × k-schedule ×
//!   buckets (`none`/`layers`/`bytes:N`) × bucket apportionment ×
//!   parallelism (`serial`/`threads:N`/`pool:N`)}; a [`SearchSpace`] is a
//!   cross-product of axis value lists, enumerated in a deterministic
//!   order with config-equivalent duplicates collapsed.
//! * [`strategy`] — pluggable [`SearchStrategy`] implementations over a
//!   [`CostOracle`]: [`ExhaustiveGrid`] (score everything),
//!   [`GreedyDescent`] (coordinate descent over the axes), and
//!   [`SuccessiveHalving`] (cheap low-fidelity rungs eliminate most of
//!   the cohort; survivors are re-scored at full fidelity and can be
//!   *promoted to short real training runs* whose measured
//!   `StepRecord` wall time picks the final winner).
//! * [`plan`] — the [`TunedPlan`] artifact: a self-describing JSON file
//!   (scenario, seed, strategy, chosen candidate, leaderboard,
//!   per-bucket budgets) that `sparkv train --plan` maps back onto the
//!   ordinary `[train]` config keys. Replay therefore goes through the
//!   existing `Scheduler`/`BucketSchedule`/`Executor` seams with their
//!   semantics untouched — a plan run is bit-identical to the same
//!   config written by hand (`tests/autotune_plan.rs`).
//!
//! ## Determinism
//!
//! A fixed `(scenario, space, strategy, seed)` quadruple yields a
//! byte-identical plan: candidate enumeration is ordered, the oracle is
//! pure f64 arithmetic over the deterministic netsim timeline, ranking
//! ties break by enumeration order, and the only randomness — successive
//! halving's optional cohort subsample — draws from a `Pcg64` seeded
//! with the plan seed. The default scenario's plan is golden-pinned
//! (`tests/golden/tuned_plan.json`); the seed ⇒ bit-identity property is
//! locked in `tests/autotune_plan.rs`. Measured promotion and
//! calibration are the deliberate exceptions (they exist to pull *this
//! machine's* constants into the loop) and are off unless explicitly
//! requested.
//!
//! ## Calibration
//!
//! The stock oracle uses the paper-calibrated V100/10 GbE constants. A
//! [`Calibrator`] run replaces the machine-dependent ones with measured
//! values: per-runtime launch overhead from `StepRecord`'s
//! `spawn_or_dispatch_us` trace, a compute scale from measured serial
//! step wall time, and a link-bandwidth scale from a timed in-process
//! ring all-reduce. The fitted [`Calibration`] is recorded in the plan so
//! a tuned artifact says which machine's constants ranked it.

pub mod calibrate;
pub mod oracle;
pub mod plan;
pub mod space;
pub mod strategy;

pub use calibrate::{Calibration, Calibrator};
pub use oracle::{CandidateCost, CostOracle};
pub use plan::{tune, TunedPlan, DEFAULT_TUNE_SEED, PLAN_VERSION};
pub use space::{Candidate, SearchSpace, TuneScenario};
pub use strategy::{
    ExhaustiveGrid, GreedyDescent, ScoredCandidate, SearchResult, SearchStrategy,
    SuccessiveHalving,
};
