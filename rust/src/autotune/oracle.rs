//! The search loop's cost oracle: predicted epoch wall-clock for one
//! candidate, from the netsim timeline plus the per-runtime launch
//! overhead model (optionally replaced by measured, calibrated
//! constants).

use super::calibrate::Calibration;
use super::space::{Candidate, TuneScenario};
use crate::config::Parallelism;
use crate::netsim::{
    runtime_overhead_s, runtime_overhead_with, OpCostModel, SimConfig, Simulator,
    WIRE_PACK_PER_ELEM_S,
};
use crate::schedule::density_trace;

/// Modeled fraction of steps a `warm:TAU` candidate serves from its
/// cached threshold. Gradient magnitude distributions are stable across
/// adjacent steps (the paper's Fig. 2/7 observation the warm engine is
/// built on), so after the cold seed nearly every step stays inside the
/// drift band; the measured bench (`BENCH_select.json`) reports the real
/// per-schedule hit rates this constant abstracts.
pub const WARM_HIT_RATE: f64 = 0.9;

/// Per-element cost of the fused warm scan on a hit step: one linear
/// pass doing the threshold partition, Σu² mass, and histogram fill
/// together — cheaper than every cold derivation (TopK's full
/// quickselect at 12 ns/elem, GaussianK's fit + refinement passes at
/// 0.9 ns/elem) because it touches each element exactly once with no
/// data-dependent re-passes.
pub const WARM_SCAN_PER_ELEM_S: f64 = 0.6e-9;

/// Predicted cost of one candidate over one virtual epoch.
#[derive(Debug, Clone)]
pub struct CandidateCost {
    /// Σ per-step predicted iteration time (the ranking key).
    pub epoch_s: f64,
    pub mean_iter_s: f64,
    /// Σ per-step communication / selection time (diagnostics).
    pub comm_s: f64,
    pub select_s: f64,
    /// The per-iteration host-runtime overhead this candidate's
    /// parallelism was charged.
    pub host_overhead_s: f64,
    /// Virtual steps the prediction summed (the fidelity — successive
    /// halving scores early rungs at a fraction of the epoch).
    pub steps: usize,
}

/// Scores candidates with [`Simulator::iteration_at_ratio`] over the
/// candidate's per-step density trace, plus [`runtime_overhead_s`] for
/// the worker runtime. Two modelling choices tie the prediction to the
/// real trainer:
///
/// * the pipeline-overlap credit is **derived from the collective
///   engine itself**: a candidate whose engine executes the exchange off
///   the coordinator thread
///   ([`crate::collectives::Collectives::off_coordinator`]) is priced at
///   the pipelined `total`; one whose engine runs on the coordinator is
///   charged the *serialized* schedule, the simulator's
///   `total + overlap_saved`. Today that means `serial` is serialized
///   while both `threads:N` (scoped per-rank threads) and `pool:N` (the
///   persistent ring rig behind
///   [`crate::collectives::PooledRingCollectives`]) earn the credit.
///   Deriving the flag from the engine rather than matching on
///   [`Parallelism`] keeps the oracle honest across engine changes: PR 6
///   hardcoded `pool:N` as serialized because its collectives then ran
///   on the coordinator, and that charge silently became wrong the
///   moment PR 7 made the pooled ring real. (Pinned by
///   `pool_earns_the_pipeline_credit_of_its_ring_engine` below.)
/// * the host overhead is the launch cost of the runtime
///   (spawn-per-step for `threads:N`, channel dispatch for `pool:N`,
///   zero for `serial`), with the same thread-budget capping the trainer
///   applies — measured twins replace the constants under a
///   [`Calibration`].
///
/// The oracle is pure f64 arithmetic over a deterministic timeline: a
/// given `(scenario, calibration, candidate, fidelity)` always yields
/// bit-identical costs — the foundation of the plan determinism
/// contract.
///
/// One axis is invisible to it: `bucket_apportion` redistributes the
/// per-bucket wire budget but never resizes it, so `mass` and `size`
/// candidates score identically here. Ranking that axis needs the
/// measured leg (`SuccessiveHalving::measure` in `super::strategy`); the
/// default space pins it to `size` for exactly this reason.
pub struct CostOracle<'a> {
    scenario: &'a TuneScenario,
    calibration: Option<&'a Calibration>,
}

impl<'a> CostOracle<'a> {
    pub fn new(scenario: &'a TuneScenario, calibration: Option<&'a Calibration>) -> CostOracle<'a> {
        CostOracle {
            scenario,
            calibration,
        }
    }

    pub fn scenario(&self) -> &TuneScenario {
        self.scenario
    }

    /// The per-iteration host overhead charged to `parallelism`: the
    /// stock [`runtime_overhead_s`] model, or the same formula
    /// ([`runtime_overhead_with`]) with the calibrated per-thread
    /// constants — one capping/dispatch rule for both paths.
    pub fn host_overhead_s(&self, parallelism: Parallelism) -> f64 {
        let workers = self.scenario.workers();
        match self.calibration {
            None => runtime_overhead_s(parallelism, workers),
            Some(c) => runtime_overhead_with(
                parallelism,
                workers,
                c.spawn_per_thread_s,
                c.pool_dispatch_per_thread_s,
            ),
        }
    }

    /// Predicted cost over the scenario's full epoch.
    pub fn predict(&self, cand: &Candidate) -> CandidateCost {
        self.predict_at_fidelity(cand, self.scenario.steps_per_epoch)
    }

    /// Predicted cost over the first `steps` virtual steps of the epoch
    /// (the successive-halving fidelity knob; `steps == steps_per_epoch`
    /// is the full prediction).
    pub fn predict_at_fidelity(&self, cand: &Candidate, steps: usize) -> CandidateCost {
        let scen = self.scenario;
        let steps = steps.max(1);
        let trace = density_trace(&cand.k_schedule, scen.k_ratio, scen.steps_per_epoch, steps);

        let mut model = scen.model.clone();
        let mut topo = scen.topo.clone();
        if let Some(c) = self.calibration {
            model.t1_compute *= c.compute_scale;
            topo.intra.bandwidth_bps *= c.bandwidth_scale;
            topo.inter.bandwidth_bps *= c.bandwidth_scale;
        }
        let host_overhead_s = self.host_overhead_s(cand.parallelism);
        // Overlap capability comes from the engine, not the parallelism
        // tag: an engine that keeps the exchange on the coordinator
        // thread serializes the bucket loop, so it is charged
        // `total + overlap_saved` (which reconstructs the serialized
        // schedule exactly — see `IterationBreakdown::overlap_saved`).
        let serialized = !cand.parallelism.engine().off_coordinator();

        let mut sim = Simulator::new(SimConfig {
            topo,
            model,
            op: cand.op,
            k_ratio: scen.k_ratio,
            straggler_sigma: 0.0,
            seed: 1,
            buckets: scen.sim_buckets(cand.buckets),
            host_overhead_s,
            exchange: cand.exchange,
            // The wire axis prices through the simulator: encoded link
            // bytes via `WireCodec::model_bytes`, plus encode/decode CPU
            // at the (calibrator-replaceable) per-element constant.
            wire: cand.wire,
            wire_cpu_per_elem_s: self
                .calibration
                .map_or(WIRE_PACK_PER_ELEM_S, |c| c.wire_pack_per_elem_s),
        });
        // Warm-selection credit: a `warm:TAU` candidate on a thresholded
        // operator replaces the cold per-step derivation with the fused
        // single scan on hit steps. Expected per-step selection becomes
        // `HIT_RATE·scan + (1 − HIT_RATE)·cold`, clamped so warm never
        // scores below its own cold fallback; the difference comes off
        // the critical path (selection precedes the exchange in the
        // simulated timeline).
        let warm_credit = cand.select.is_warm() && cand.op.warm_eligible();
        let warm_scan_s = OpCostModel::for_op(cand.op).fixed_s
            + WARM_SCAN_PER_ELEM_S * scen.model.params as f64;

        let (mut epoch_s, mut comm_s, mut select_s) = (0.0f64, 0.0f64, 0.0f64);
        for &rho in &trace {
            let b = sim.iteration_at_ratio(rho);
            let mut iter = if serialized { b.total + b.overlap_saved } else { b.total };
            let mut sel = b.select;
            if warm_credit {
                let warm = (WARM_HIT_RATE * warm_scan_s + (1.0 - WARM_HIT_RATE) * b.select)
                    .min(b.select);
                iter -= b.select - warm;
                sel = warm;
            }
            epoch_s += iter;
            comm_s += b.comm;
            select_s += sel;
        }
        CandidateCost {
            epoch_s,
            mean_iter_s: epoch_s / steps as f64,
            comm_s,
            select_s,
            host_overhead_s,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::OpKind;
    use crate::config::{BucketApportion, Buckets};
    use crate::schedule::KSchedule;

    fn cand(op: OpKind, buckets: Buckets, parallelism: Parallelism) -> Candidate {
        Candidate {
            op,
            k_schedule: KSchedule::Const(None),
            buckets,
            bucket_apportion: BucketApportion::Size,
            parallelism,
            exchange: crate::config::Exchange::DenseRing,
            select: crate::config::Select::Exact,
            wire: crate::tensor::wire::WireCodec::Raw,
        }
        .normalized()
    }

    #[test]
    fn predictions_are_deterministic_and_positive() {
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Pool(4));
        let a = oracle.predict(&c);
        let b = oracle.predict(&c);
        assert_eq!(a.epoch_s.to_bits(), b.epoch_s.to_bits());
        assert!(a.epoch_s > 0.0 && a.epoch_s.is_finite());
        assert_eq!(a.steps, scen.steps_per_epoch);
        assert!((a.mean_iter_s - a.epoch_s / a.steps as f64).abs() < 1e-15);
    }

    #[test]
    fn monolithic_epoch_matches_simulator_sum() {
        // The oracle is exactly the scheduled netsim timeline plus the
        // runtime overhead: cross-check against a hand-driven simulator.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let got = oracle.predict(&c);
        let mut sim = Simulator::new(SimConfig {
            topo: scen.topo.clone(),
            model: scen.model.clone(),
            op: OpKind::TopK,
            k_ratio: scen.k_ratio,
            straggler_sigma: 0.0,
            seed: 1,
            buckets: 1,
            host_overhead_s: 0.0,
            exchange: crate::config::Exchange::DenseRing,
            wire: crate::tensor::wire::WireCodec::Raw,
            wire_cpu_per_elem_s: WIRE_PACK_PER_ELEM_S,
        });
        let mut want = 0.0f64;
        for _ in 0..scen.steps_per_epoch {
            want += sim.iteration_at_ratio(scen.k_ratio).total;
        }
        assert_eq!(got.epoch_s.to_bits(), want.to_bits());
        assert_eq!(got.host_overhead_s, 0.0);
    }

    #[test]
    fn pool_earns_the_pipeline_credit_of_its_ring_engine() {
        // The PR-7 flip of the PR-6 charging audit: `pool:N` collectives
        // now execute on the pool's persistent ring threads
        // (`PooledRingCollectives::off_coordinator() == true`), so the
        // oracle credits the pooled bucketed timeline with the same
        // pipeline overlap as `threads:N` — the two differ only by their
        // launch-overhead constants. Serial remains the one serialized
        // runtime, because its engine is the only one still running the
        // exchange on the coordinator thread.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let serial = oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Serial));
        let pooled =
            oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Pool(4)));
        let threaded =
            oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Threads(4)));
        // Pool and threads share the pipelined timeline: strip each
        // runtime's per-step launch bill and the remainders agree.
        let pool_core = pooled.epoch_s - pooled.host_overhead_s * pooled.steps as f64;
        let thread_core = threaded.epoch_s - threaded.host_overhead_s * threaded.steps as f64;
        assert!(
            (pool_core - thread_core).abs() < 1e-9,
            "pool core {pool_core} != threads core {thread_core}"
        );
        // The pipeline credit dwarfs the dispatch bill on this
        // communication-heavy bucketed timeline: pooled beats serial.
        assert!(
            pooled.epoch_s < serial.epoch_s,
            "pool {0} !< serial {1}: the ring engine's overlap credit vanished",
            pooled.epoch_s,
            serial.epoch_s
        );
        // And the µs-scale dispatch constant keeps pool under threads.
        assert!(pooled.epoch_s < threaded.epoch_s);
        // Serial pays zero launch overhead; pool pays its dispatch model;
        // runtime ordering of launch overhead matches the netsim model.
        assert_eq!(serial.host_overhead_s, 0.0);
        assert!(pooled.host_overhead_s > 0.0);
        assert!(threaded.host_overhead_s > pooled.host_overhead_s);
        // Monolithic timelines have no overlap to credit: pool is exactly
        // serial plus its dispatch bill, pipelining or not.
        let mono_serial = oracle.predict(&cand(OpKind::GaussianK, Buckets::None, Parallelism::Serial));
        let mono_pool = oracle.predict(&cand(OpKind::GaussianK, Buckets::None, Parallelism::Pool(4)));
        let want = mono_serial.epoch_s + mono_pool.host_overhead_s * mono_pool.steps as f64;
        assert!((mono_pool.epoch_s - want).abs() < 1e-12);
    }

    #[test]
    fn tree_exchange_prices_into_the_prediction() {
        // Same candidate, tree wire schedule: cheaper comm at the paper's
        // 16-GPU scale, identical compute/select/launch charges.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let ring = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let mut tree = ring.clone();
        tree.exchange = crate::config::Exchange::TreeSparse;
        let r = oracle.predict(&ring);
        let t = oracle.predict(&tree);
        assert!(t.comm_s < r.comm_s, "tree {} !< ring {}", t.comm_s, r.comm_s);
        assert!(t.epoch_s < r.epoch_s);
        assert_eq!(t.select_s.to_bits(), r.select_s.to_bits());
        assert_eq!(t.host_overhead_s.to_bits(), r.host_overhead_s.to_bits());
    }

    #[test]
    fn warm_selection_earns_a_scan_credit() {
        use crate::config::Select;
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let exact = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let mut warm = exact.clone();
        warm.select = Select::Warm { tau: 0.25 };
        let e = oracle.predict(&exact);
        let w = oracle.predict(&warm);
        // Warm selection is cheaper, and the entire saving comes off the
        // serialized critical path (comm and launch are untouched).
        assert!(w.select_s < e.select_s, "warm {} !< exact {}", w.select_s, e.select_s);
        assert!((e.epoch_s - w.epoch_s - (e.select_s - w.select_s)).abs() < 1e-9);
        assert_eq!(w.comm_s.to_bits(), e.comm_s.to_bits());
        // TopK's quickselect constant dwarfs the fused scan: the hit-rate
        // blend saves more than half the cold selection bill.
        assert!(w.select_s < e.select_s * 0.5);
        // GaussianK's cold path is already near scan cost — warm still
        // never scores worse than exact (the clamp).
        let ge = cand(OpKind::GaussianK, Buckets::None, Parallelism::Serial);
        let mut gw = ge.clone();
        gw.select = Select::Warm { tau: 0.25 };
        assert!(oracle.predict(&gw).select_s <= oracle.predict(&ge).select_s);
        // A non-thresholded op normalizes the axis away: identical cost.
        let re = cand(OpKind::RandK, Buckets::None, Parallelism::Serial);
        let mut rw = re.clone();
        rw.select = Select::Warm { tau: 0.25 };
        let rw = rw.normalized();
        assert_eq!(
            oracle.predict(&rw).epoch_s.to_bits(),
            oracle.predict(&re).epoch_s.to_bits()
        );
    }

    #[test]
    fn calibration_overrides_constants() {
        let scen = TuneScenario::default_16gpu();
        let cal = Calibration {
            spawn_per_thread_s: 1e-3,
            pool_dispatch_per_thread_s: 1e-4,
            compute_scale: 2.0,
            bandwidth_scale: 1.0,
            wire_pack_per_elem_s: 1.0e-9,
            probe_steps: 3,
        };
        let stock = CostOracle::new(&scen, None);
        let tuned = CostOracle::new(&scen, Some(&cal));
        // Measured launch constants replace the model's.
        assert_eq!(tuned.host_overhead_s(Parallelism::Threads(4)), 4e-3);
        assert_eq!(tuned.host_overhead_s(Parallelism::Pool(4)), 4e-4);
        assert_eq!(tuned.host_overhead_s(Parallelism::Serial), 0.0);
        // Thread budget caps at the worker count like the trainer.
        assert_eq!(tuned.host_overhead_s(Parallelism::Threads(64)), 16e-3);
        // A 2× compute scale makes every candidate strictly slower.
        let c = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        assert!(tuned.predict(&c).epoch_s > stock.predict(&c).epoch_s);
        // And a faster link makes comm cheaper.
        let fast = Calibration {
            bandwidth_scale: 10.0,
            compute_scale: 1.0,
            ..cal.clone()
        };
        let fast_oracle = CostOracle::new(&scen, Some(&fast));
        let dense = cand(OpKind::Dense, Buckets::None, Parallelism::Serial);
        assert!(fast_oracle.predict(&dense).comm_s < stock.predict(&dense).comm_s);
    }

    #[test]
    fn packed_wire_prices_into_the_prediction() {
        use crate::tensor::wire::WireCodec;
        // Same candidate, packed wire: cheaper comm (fewer link bytes net
        // of the codec CPU toll at the paper's 10 GbE scale), identical
        // select/launch charges; f16 values cut comm further still.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let raw = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let mut packed = raw.clone();
        packed.wire = WireCodec::Packed;
        let mut f16 = raw.clone();
        f16.wire = WireCodec::PackedF16;
        let r = oracle.predict(&raw);
        let p = oracle.predict(&packed);
        let h = oracle.predict(&f16);
        assert!(p.comm_s < r.comm_s, "packed {} !< raw {}", p.comm_s, r.comm_s);
        assert!(h.comm_s < p.comm_s, "f16 {} !< packed {}", h.comm_s, p.comm_s);
        assert!(p.epoch_s < r.epoch_s);
        assert_eq!(p.select_s.to_bits(), r.select_s.to_bits());
        assert_eq!(p.host_overhead_s.to_bits(), r.host_overhead_s.to_bits());
        // A calibrated codec constant changes the CPU toll: an absurdly
        // expensive encoder erodes the packed advantage.
        let slow_codec = Calibration {
            spawn_per_thread_s: 1e-5,
            pool_dispatch_per_thread_s: 1e-6,
            compute_scale: 1.0,
            bandwidth_scale: 1.0,
            wire_pack_per_elem_s: 1.0e-6,
            probe_steps: 3,
        };
        let slow = CostOracle::new(&scen, Some(&slow_codec));
        let p_slow = slow.predict(&packed);
        let r_slow = slow.predict(&raw);
        assert!(
            p_slow.comm_s - r_slow.comm_s > (p.comm_s - r.comm_s),
            "raising the codec constant must raise packed's relative comm bill"
        );
    }

    #[test]
    fn fidelity_prefix_is_monotone() {
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::Dgc, Buckets::Bytes(4 << 20), Parallelism::Threads(4));
        let short = oracle.predict_at_fidelity(&c, 6);
        let full = oracle.predict(&c);
        assert_eq!(short.steps, 6);
        assert!(short.epoch_s < full.epoch_s);
        // Constant-density trace: mean iteration time is fidelity-free.
        assert!((short.mean_iter_s - full.mean_iter_s).abs() < 1e-12);
    }
}
