//! The search loop's cost oracle: predicted epoch wall-clock for one
//! candidate, from the netsim timeline plus the per-runtime launch
//! overhead model (optionally replaced by measured, calibrated
//! constants).

use super::calibrate::Calibration;
use super::space::{Candidate, TuneScenario};
use crate::config::Parallelism;
use crate::netsim::{runtime_overhead_s, runtime_overhead_with, SimConfig, Simulator};
use crate::schedule::density_trace;

/// Predicted cost of one candidate over one virtual epoch.
#[derive(Debug, Clone)]
pub struct CandidateCost {
    /// Σ per-step predicted iteration time (the ranking key).
    pub epoch_s: f64,
    pub mean_iter_s: f64,
    /// Σ per-step communication / selection time (diagnostics).
    pub comm_s: f64,
    pub select_s: f64,
    /// The per-iteration host-runtime overhead this candidate's
    /// parallelism was charged.
    pub host_overhead_s: f64,
    /// Virtual steps the prediction summed (the fidelity — successive
    /// halving scores early rungs at a fraction of the epoch).
    pub steps: usize,
}

/// Scores candidates with [`Simulator::iteration_at_ratio`] over the
/// candidate's per-step density trace, plus [`runtime_overhead_s`] for
/// the worker runtime. Two modelling choices tie the prediction to the
/// real trainer:
///
/// * the **serial** runtime runs the bucket loop without the pipeline,
///   and the **pool** runtime's collectives are the serial schedule
///   executed *on the coordinator thread*
///   ([`crate::collectives::PooledCollectives`] delegates to the serial
///   oracle with zero thread activity per call) — so both are charged
///   the *serialized* schedule, the simulator's `total + overlap_saved`,
///   plus their respective launch overheads. Only `threads:N` gets the
///   pipeline-overlap credit, because only its per-rank scoped engine
///   actually executes the exchange off the coordinator thread. (The
///   oracle used to hand `pool:N` the overlap credit too, which made
///   pooled bucketed plans win every leaderboard by modelling a pipeline
///   the pooled collective path cannot realize — pinned by
///   `pool_is_charged_the_serialized_bucket_schedule` below.)
/// * the host overhead is the launch cost of the runtime
///   (spawn-per-step for `threads:N`, channel dispatch for `pool:N`,
///   zero for `serial`), with the same thread-budget capping the trainer
///   applies — measured twins replace the constants under a
///   [`Calibration`].
///
/// The oracle is pure f64 arithmetic over a deterministic timeline: a
/// given `(scenario, calibration, candidate, fidelity)` always yields
/// bit-identical costs — the foundation of the plan determinism
/// contract.
///
/// One axis is invisible to it: `bucket_apportion` redistributes the
/// per-bucket wire budget but never resizes it, so `mass` and `size`
/// candidates score identically here. Ranking that axis needs the
/// measured leg (`SuccessiveHalving::measure` in `super::strategy`); the
/// default space pins it to `size` for exactly this reason.
pub struct CostOracle<'a> {
    scenario: &'a TuneScenario,
    calibration: Option<&'a Calibration>,
}

impl<'a> CostOracle<'a> {
    pub fn new(scenario: &'a TuneScenario, calibration: Option<&'a Calibration>) -> CostOracle<'a> {
        CostOracle {
            scenario,
            calibration,
        }
    }

    pub fn scenario(&self) -> &TuneScenario {
        self.scenario
    }

    /// The per-iteration host overhead charged to `parallelism`: the
    /// stock [`runtime_overhead_s`] model, or the same formula
    /// ([`runtime_overhead_with`]) with the calibrated per-thread
    /// constants — one capping/dispatch rule for both paths.
    pub fn host_overhead_s(&self, parallelism: Parallelism) -> f64 {
        let workers = self.scenario.workers();
        match self.calibration {
            None => runtime_overhead_s(parallelism, workers),
            Some(c) => runtime_overhead_with(
                parallelism,
                workers,
                c.spawn_per_thread_s,
                c.pool_dispatch_per_thread_s,
            ),
        }
    }

    /// Predicted cost over the scenario's full epoch.
    pub fn predict(&self, cand: &Candidate) -> CandidateCost {
        self.predict_at_fidelity(cand, self.scenario.steps_per_epoch)
    }

    /// Predicted cost over the first `steps` virtual steps of the epoch
    /// (the successive-halving fidelity knob; `steps == steps_per_epoch`
    /// is the full prediction).
    pub fn predict_at_fidelity(&self, cand: &Candidate, steps: usize) -> CandidateCost {
        let scen = self.scenario;
        let steps = steps.max(1);
        let trace = density_trace(&cand.k_schedule, scen.k_ratio, scen.steps_per_epoch, steps);

        let mut model = scen.model.clone();
        let mut topo = scen.topo.clone();
        if let Some(c) = self.calibration {
            model.t1_compute *= c.compute_scale;
            topo.intra.bandwidth_bps *= c.bandwidth_scale;
            topo.inter.bandwidth_bps *= c.bandwidth_scale;
        }
        let host_overhead_s = self.host_overhead_s(cand.parallelism);
        // The serial runtime walks buckets without the pipeline, and the
        // pooled runtime's collectives run serially on the coordinator
        // thread (`PooledCollectives`): charge both the serialized
        // schedule (total + overlap_saved reconstructs it exactly — see
        // `IterationBreakdown::overlap_saved`). Only the scoped
        // thread-per-rank runtime earns the pipeline-overlap credit.
        let serialized = matches!(
            cand.parallelism,
            Parallelism::Serial | Parallelism::Pool(_)
        );

        let mut sim = Simulator::new(SimConfig {
            topo,
            model,
            op: cand.op,
            k_ratio: scen.k_ratio,
            straggler_sigma: 0.0,
            seed: 1,
            buckets: scen.sim_buckets(cand.buckets),
            host_overhead_s,
            exchange: cand.exchange,
        });
        let (mut epoch_s, mut comm_s, mut select_s) = (0.0f64, 0.0f64, 0.0f64);
        for &rho in &trace {
            let b = sim.iteration_at_ratio(rho);
            let iter = if serialized { b.total + b.overlap_saved } else { b.total };
            epoch_s += iter;
            comm_s += b.comm;
            select_s += b.select;
        }
        CandidateCost {
            epoch_s,
            mean_iter_s: epoch_s / steps as f64,
            comm_s,
            select_s,
            host_overhead_s,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::OpKind;
    use crate::config::{BucketApportion, Buckets};
    use crate::schedule::KSchedule;

    fn cand(op: OpKind, buckets: Buckets, parallelism: Parallelism) -> Candidate {
        Candidate {
            op,
            k_schedule: KSchedule::Const(None),
            buckets,
            bucket_apportion: BucketApportion::Size,
            parallelism,
            exchange: crate::config::Exchange::DenseRing,
        }
        .normalized()
    }

    #[test]
    fn predictions_are_deterministic_and_positive() {
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Pool(4));
        let a = oracle.predict(&c);
        let b = oracle.predict(&c);
        assert_eq!(a.epoch_s.to_bits(), b.epoch_s.to_bits());
        assert!(a.epoch_s > 0.0 && a.epoch_s.is_finite());
        assert_eq!(a.steps, scen.steps_per_epoch);
        assert!((a.mean_iter_s - a.epoch_s / a.steps as f64).abs() < 1e-15);
    }

    #[test]
    fn monolithic_epoch_matches_simulator_sum() {
        // The oracle is exactly the scheduled netsim timeline plus the
        // runtime overhead: cross-check against a hand-driven simulator.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let got = oracle.predict(&c);
        let mut sim = Simulator::new(SimConfig {
            topo: scen.topo.clone(),
            model: scen.model.clone(),
            op: OpKind::TopK,
            k_ratio: scen.k_ratio,
            straggler_sigma: 0.0,
            seed: 1,
            buckets: 1,
            host_overhead_s: 0.0,
            exchange: crate::config::Exchange::DenseRing,
        });
        let mut want = 0.0f64;
        for _ in 0..scen.steps_per_epoch {
            want += sim.iteration_at_ratio(scen.k_ratio).total;
        }
        assert_eq!(got.epoch_s.to_bits(), want.to_bits());
        assert_eq!(got.host_overhead_s, 0.0);
    }

    #[test]
    fn pool_is_charged_the_serialized_bucket_schedule() {
        // The satellite charging audit: `PooledCollectives` executes the
        // serial collective schedule on the coordinator thread, so the
        // oracle must not credit `pool:N` with pipeline overlap it cannot
        // realize. Serial and pool both pay the serialized schedule
        // (differing only by the pool's µs-scale dispatch bill); only the
        // scoped thread-per-rank runtime earns the overlap credit.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let serial = oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Serial));
        let pooled =
            oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Pool(4)));
        let threaded =
            oracle.predict(&cand(OpKind::GaussianK, Buckets::Layers, Parallelism::Threads(4)));
        // Pool = serialized schedule + dispatch overhead, exactly.
        let expected_pool = serial.epoch_s + pooled.host_overhead_s * pooled.steps as f64;
        assert!(
            (pooled.epoch_s - expected_pool).abs() < 1e-12,
            "pool {} != serialized {} + dispatch",
            pooled.epoch_s,
            expected_pool
        );
        // The overlap credit goes to threads alone, and it dwarfs the
        // spawn bill on this communication-heavy bucketed timeline.
        assert!(
            threaded.epoch_s < pooled.epoch_s,
            "threads {0} !< pool {1}: the pipeline credit vanished",
            threaded.epoch_s,
            pooled.epoch_s
        );
        // Serial pays zero launch overhead; pool pays its dispatch model;
        // runtime ordering of launch overhead matches the netsim model.
        assert_eq!(serial.host_overhead_s, 0.0);
        assert!(pooled.host_overhead_s > 0.0);
        assert!(threaded.host_overhead_s > pooled.host_overhead_s);
        // Monolithic timelines have no overlap to credit: all three
        // runtimes differ only by their launch overhead.
        let mono_serial = oracle.predict(&cand(OpKind::GaussianK, Buckets::None, Parallelism::Serial));
        let mono_pool = oracle.predict(&cand(OpKind::GaussianK, Buckets::None, Parallelism::Pool(4)));
        let want = mono_serial.epoch_s + mono_pool.host_overhead_s * mono_pool.steps as f64;
        assert!((mono_pool.epoch_s - want).abs() < 1e-12);
    }

    #[test]
    fn tree_exchange_prices_into_the_prediction() {
        // Same candidate, tree wire schedule: cheaper comm at the paper's
        // 16-GPU scale, identical compute/select/launch charges.
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let ring = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        let mut tree = ring.clone();
        tree.exchange = crate::config::Exchange::TreeSparse;
        let r = oracle.predict(&ring);
        let t = oracle.predict(&tree);
        assert!(t.comm_s < r.comm_s, "tree {} !< ring {}", t.comm_s, r.comm_s);
        assert!(t.epoch_s < r.epoch_s);
        assert_eq!(t.select_s.to_bits(), r.select_s.to_bits());
        assert_eq!(t.host_overhead_s.to_bits(), r.host_overhead_s.to_bits());
    }

    #[test]
    fn calibration_overrides_constants() {
        let scen = TuneScenario::default_16gpu();
        let cal = Calibration {
            spawn_per_thread_s: 1e-3,
            pool_dispatch_per_thread_s: 1e-4,
            compute_scale: 2.0,
            bandwidth_scale: 1.0,
            probe_steps: 3,
        };
        let stock = CostOracle::new(&scen, None);
        let tuned = CostOracle::new(&scen, Some(&cal));
        // Measured launch constants replace the model's.
        assert_eq!(tuned.host_overhead_s(Parallelism::Threads(4)), 4e-3);
        assert_eq!(tuned.host_overhead_s(Parallelism::Pool(4)), 4e-4);
        assert_eq!(tuned.host_overhead_s(Parallelism::Serial), 0.0);
        // Thread budget caps at the worker count like the trainer.
        assert_eq!(tuned.host_overhead_s(Parallelism::Threads(64)), 16e-3);
        // A 2× compute scale makes every candidate strictly slower.
        let c = cand(OpKind::TopK, Buckets::None, Parallelism::Serial);
        assert!(tuned.predict(&c).epoch_s > stock.predict(&c).epoch_s);
        // And a faster link makes comm cheaper.
        let fast = Calibration {
            bandwidth_scale: 10.0,
            compute_scale: 1.0,
            ..cal.clone()
        };
        let fast_oracle = CostOracle::new(&scen, Some(&fast));
        let dense = cand(OpKind::Dense, Buckets::None, Parallelism::Serial);
        assert!(fast_oracle.predict(&dense).comm_s < stock.predict(&dense).comm_s);
    }

    #[test]
    fn fidelity_prefix_is_monotone() {
        let scen = TuneScenario::default_16gpu();
        let oracle = CostOracle::new(&scen, None);
        let c = cand(OpKind::Dgc, Buckets::Bytes(4 << 20), Parallelism::Threads(4));
        let short = oracle.predict_at_fidelity(&c, 6);
        let full = oracle.predict(&c);
        assert_eq!(short.steps, 6);
        assert!(short.epoch_s < full.epoch_s);
        // Constant-density trace: mean iteration time is fidelity-free.
        assert!((short.mean_iter_s - full.mean_iter_s).abs() < 1e-12);
    }
}
