//! Pluggable search strategies over the [`CostOracle`]: exhaustive grid,
//! greedy coordinate descent, and successive halving with optional
//! promotion of the survivors to short *measured* training runs.

use super::oracle::{CandidateCost, CostOracle};
use super::space::{Candidate, SearchSpace};
use crate::stats::rng::Pcg64;

/// One evaluated candidate: its predicted cost and, when a measured
/// promotion ran, the mean measured step wall-clock of the probe run.
#[derive(Debug, Clone)]
pub struct ScoredCandidate {
    pub candidate: Candidate,
    pub cost: CandidateCost,
    /// Mean measured seconds per step of the promotion probe (successive
    /// halving with measurement only).
    pub measured_step_s: Option<f64>,
}

/// A strategy's outcome: candidates ranked best-first (the ranking key is
/// predicted epoch time, except that measured promotion re-orders the
/// measured survivors by their probe wall-clock), plus how many oracle
/// evaluations the search spent.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub ranked: Vec<ScoredCandidate>,
    pub evaluated: usize,
}

/// A search procedure over the candidate space. Implementations must be
/// deterministic functions of `(space, oracle, seed)` — any randomness
/// draws from a `Pcg64` seeded with `seed` — except where a measured
/// probe is explicitly wired in ([`SuccessiveHalving::measure`]).
pub trait SearchStrategy {
    /// Identity string recorded in the plan (round-trip parseable by the
    /// CLI's strategy selector for the parameter-free strategies).
    fn name(&self) -> String;

    fn search(&mut self, space: &SearchSpace, oracle: &CostOracle, seed: u64) -> SearchResult;
}

fn rank(mut scored: Vec<ScoredCandidate>) -> Vec<ScoredCandidate> {
    // Stable sort: ties keep enumeration (first-evaluation) order, which
    // is what makes argmin deterministic under equal costs.
    scored.sort_by(|a, b| a.cost.epoch_s.total_cmp(&b.cost.epoch_s));
    scored
}

/// Score every candidate in the space at full fidelity. O(|space|) oracle
/// calls — the reference strategy, and the one the golden plan pins.
#[derive(Debug, Default)]
pub struct ExhaustiveGrid;

impl SearchStrategy for ExhaustiveGrid {
    fn name(&self) -> String {
        "grid".to_string()
    }

    fn search(&mut self, space: &SearchSpace, oracle: &CostOracle, _seed: u64) -> SearchResult {
        let scored: Vec<ScoredCandidate> = space
            .enumerate()
            .into_iter()
            .map(|candidate| {
                let cost = oracle.predict(&candidate);
                ScoredCandidate {
                    candidate,
                    cost,
                    measured_step_s: None,
                }
            })
            .collect();
        let evaluated = scored.len();
        SearchResult {
            ranked: rank(scored),
            evaluated,
        }
    }
}

/// Coordinate descent over the six axes: start from the space's first
/// candidate, sweep axis by axis adopting any strictly-better single-axis
/// move, and stop at a fixed point (or after `max_sweeps`). Evaluates
/// O(axes · values · sweeps) candidates instead of the full cross
/// product; costs are cached by candidate name so re-visits are free.
/// Like any coordinate method it can stop at a single-axis local optimum
/// (e.g. the pipelined-bucket win requires buckets and runtime to move
/// *together*); use [`ExhaustiveGrid`] or [`SuccessiveHalving`] when the
/// space is small enough to afford it.
#[derive(Debug)]
pub struct GreedyDescent {
    pub max_sweeps: usize,
}

impl Default for GreedyDescent {
    fn default() -> Self {
        GreedyDescent { max_sweeps: 8 }
    }
}

impl SearchStrategy for GreedyDescent {
    fn name(&self) -> String {
        "greedy".to_string()
    }

    fn search(&mut self, space: &SearchSpace, oracle: &CostOracle, _seed: u64) -> SearchResult {
        let all = space.enumerate();
        let Some(start) = all.first().cloned() else {
            return SearchResult {
                ranked: Vec::new(),
                evaluated: 0,
            };
        };
        let mut cache: std::collections::BTreeMap<String, CandidateCost> =
            std::collections::BTreeMap::new();
        let mut log: Vec<ScoredCandidate> = Vec::new();
        let mut evaluated = 0usize;
        let score = |c: &Candidate,
                         cache: &mut std::collections::BTreeMap<String, CandidateCost>,
                         log: &mut Vec<ScoredCandidate>,
                         evaluated: &mut usize|
         -> CandidateCost {
            let key = c.name();
            if let Some(hit) = cache.get(&key) {
                return hit.clone();
            }
            let cost = oracle.predict(c);
            *evaluated += 1;
            cache.insert(key, cost.clone());
            log.push(ScoredCandidate {
                candidate: c.clone(),
                cost: cost.clone(),
                measured_step_s: None,
            });
            cost
        };

        let mut current = start;
        let mut best = score(&current, &mut cache, &mut log, &mut evaluated);
        for _ in 0..self.max_sweeps.max(1) {
            let mut improved = false;
            for axis in 0..8 {
                // Axis values in space order; the move keeps every other
                // axis fixed and renormalizes.
                let moves: Vec<Candidate> = match axis {
                    0 => space
                        .ops
                        .iter()
                        .map(|&op| Candidate { op, ..current.clone() })
                        .collect(),
                    1 => space
                        .k_schedules
                        .iter()
                        .map(|&k_schedule| Candidate {
                            k_schedule,
                            ..current.clone()
                        })
                        .collect(),
                    2 => space
                        .buckets
                        .iter()
                        .map(|&buckets| Candidate {
                            buckets,
                            ..current.clone()
                        })
                        .collect(),
                    3 => space
                        .apportions
                        .iter()
                        .map(|&bucket_apportion| Candidate {
                            bucket_apportion,
                            ..current.clone()
                        })
                        .collect(),
                    4 => space
                        .parallelisms
                        .iter()
                        .map(|&parallelism| Candidate {
                            parallelism,
                            ..current.clone()
                        })
                        .collect(),
                    5 => space
                        .exchanges
                        .iter()
                        .map(|&exchange| Candidate {
                            exchange,
                            ..current.clone()
                        })
                        .collect(),
                    6 => space
                        .selects
                        .iter()
                        .map(|&select| Candidate {
                            select,
                            ..current.clone()
                        })
                        .collect(),
                    _ => space
                        .wires
                        .iter()
                        .map(|&wire| Candidate {
                            wire,
                            ..current.clone()
                        })
                        .collect(),
                };
                for cand in moves {
                    let cand = cand.normalized();
                    let cost = score(&cand, &mut cache, &mut log, &mut evaluated);
                    if cost.epoch_s < best.epoch_s {
                        current = cand;
                        best = cost;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        SearchResult {
            ranked: rank(log),
            evaluated,
        }
    }
}

/// A measured promotion probe: trains a candidate for a handful of real
/// steps and returns the mean measured wall-clock per step (from the run's
/// `StepRecord` trace). Wired in by the CLI's `--measure` flag; absent in
/// library/test use, which keeps the strategy fully deterministic.
pub type MeasureProbe<'a> = Box<dyn FnMut(&Candidate) -> anyhow::Result<f64> + 'a>;

/// Successive halving: score the whole cohort at a cheap low fidelity
/// (a fraction of the virtual epoch), keep the best `1/eta`, re-score at
/// higher fidelity, and repeat until the final rung runs at full
/// fidelity. With [`SuccessiveHalving::measure`] wired, the top survivors
/// are then *promoted to short real training runs* and the winner among
/// them is picked by measured step wall-clock — the closed loop's
/// measured leg.
pub struct SuccessiveHalving<'a> {
    /// Elimination factor per rung (≥ 2).
    pub eta: usize,
    /// Number of rungs (the last one runs at full fidelity).
    pub rungs: usize,
    /// Optional seeded subsample of the cohort before rung 0 (for big
    /// spaces); `None` starts from the full enumeration.
    pub sample: Option<usize>,
    /// How many final-rung survivors get a measured promotion run.
    pub promote: usize,
    /// The measured probe (None ⇒ fully deterministic, simulation-only).
    pub measure: Option<MeasureProbe<'a>>,
}

impl Default for SuccessiveHalving<'_> {
    fn default() -> Self {
        SuccessiveHalving {
            eta: 2,
            rungs: 3,
            sample: None,
            promote: 2,
            measure: None,
        }
    }
}

impl SearchStrategy for SuccessiveHalving<'_> {
    fn name(&self) -> String {
        let mut n = format!("halving:eta={},rungs={}", self.eta.max(2), self.rungs.max(1));
        if let Some(m) = self.sample {
            n.push_str(&format!(",sample={m}"));
        }
        if self.measure.is_some() {
            n.push_str(",measured");
        }
        n
    }

    fn search(&mut self, space: &SearchSpace, oracle: &CostOracle, seed: u64) -> SearchResult {
        let eta = self.eta.max(2);
        let rungs = self.rungs.max(1);
        let full = oracle.scenario().steps_per_epoch.max(1);
        let mut cohort = space.enumerate();
        // Seeded cohort subsample (partial Fisher–Yates: deterministic
        // per seed, order-preserving in the kept prefix).
        if let Some(m) = self.sample {
            if m < cohort.len() {
                let mut rng = Pcg64::seed(seed);
                let len = cohort.len();
                for i in 0..m {
                    let j = i + rng.next_below((len - i) as u64) as usize;
                    cohort.swap(i, j);
                }
                cohort.truncate(m);
            }
        }
        let mut evaluated = 0usize;
        let mut scored: Vec<ScoredCandidate> = Vec::new();
        let mut eliminated: Vec<ScoredCandidate> = Vec::new();
        for r in 0..rungs {
            // Fidelity grows by eta per rung, reaching the full epoch at
            // the last rung: steps_r = full / eta^(rungs-1-r), floored at 1.
            let denom = eta.pow((rungs - 1 - r) as u32).max(1);
            let steps_r = (full / denom).max(1);
            scored = cohort
                .iter()
                .map(|c| {
                    evaluated += 1;
                    ScoredCandidate {
                        candidate: c.clone(),
                        cost: oracle.predict_at_fidelity(c, steps_r),
                        measured_step_s: None,
                    }
                })
                .collect();
            scored = rank(scored);
            if r + 1 < rungs {
                let keep = cohort.len().div_ceil(eta).max(1).min(scored.len());
                eliminated.extend(scored.split_off(keep));
                cohort = scored.iter().map(|s| s.candidate.clone()).collect();
            }
        }
        // Measured promotion: the top survivors train for real; among the
        // promoted, measured wall-clock decides (stable, so sim order
        // breaks measurement ties). Probe failures simply leave the
        // candidate unmeasured (sim rank retained).
        if let Some(measure) = self.measure.as_mut() {
            let promote = self.promote.clamp(1, scored.len().max(1)).min(scored.len());
            for s in scored.iter_mut().take(promote) {
                if let Ok(measured) = measure(&s.candidate) {
                    s.measured_step_s = Some(measured);
                }
            }
            scored[..promote].sort_by(|a, b| {
                match (a.measured_step_s, b.measured_step_s) {
                    (Some(x), Some(y)) => x.total_cmp(&y),
                    (Some(_), None) => std::cmp::Ordering::Less,
                    (None, Some(_)) => std::cmp::Ordering::Greater,
                    (None, None) => std::cmp::Ordering::Equal,
                }
            });
        }
        // Survivors first (full fidelity), eliminated candidates after
        // (their last-rung scores) — the leaderboard stays informative.
        scored.extend(eliminated);
        SearchResult {
            ranked: scored,
            evaluated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::space::TuneScenario;
    use crate::compress::OpKind;

    fn setup() -> (TuneScenario, SearchSpace) {
        let mut scen = TuneScenario::default_16gpu();
        scen.steps_per_epoch = 8; // keep unit tests quick
        (scen, SearchSpace::default_space())
    }

    #[test]
    fn grid_ranks_best_first_and_is_deterministic() {
        let (scen, space) = setup();
        let oracle = CostOracle::new(&scen, None);
        let a = ExhaustiveGrid.search(&space, &oracle, 7);
        let b = ExhaustiveGrid.search(&space, &oracle, 99); // seed-free
        assert_eq!(a.evaluated, space.len());
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.cost.epoch_s.to_bits(), y.cost.epoch_s.to_bits());
        }
        for w in a.ranked.windows(2) {
            assert!(w[0].cost.epoch_s <= w[1].cost.epoch_s, "ranking not sorted");
        }
        // The known physics of the space: the winner beats exact TopK
        // monolithic serial (the baseline) comfortably.
        let baseline = a
            .ranked
            .iter()
            .find(|s| s.candidate.name() == Candidate::baseline().name())
            .expect("baseline in default space");
        assert!(a.ranked[0].cost.epoch_s < baseline.cost.epoch_s);
        // And the best candidate is not RedSync-style dense-or-slower.
        assert_ne!(a.ranked[0].candidate.op, OpKind::Dense);
    }

    #[test]
    fn greedy_descends_cheaply_and_lands_near_the_grid_optimum() {
        let (scen, space) = setup();
        let oracle = CostOracle::new(&scen, None);
        let grid = ExhaustiveGrid.search(&space, &oracle, 0);
        let greedy = GreedyDescent::default().search(&space, &oracle, 0);
        assert!(
            greedy.evaluated < grid.evaluated,
            "greedy {} vs grid {}",
            greedy.evaluated,
            grid.evaluated
        );
        // Coordinate descent can stop in a single-axis local optimum (the
        // pipelined-bucket win needs buckets + runtime to move together),
        // but it must strictly improve on its start and land within a few
        // percent of the global grid optimum on this surface.
        let start_cost = greedy
            .ranked
            .iter()
            .find(|s| s.candidate == space.enumerate()[0])
            .expect("start candidate scored")
            .cost
            .epoch_s;
        let best = greedy.ranked[0].cost.epoch_s;
        assert!(best < start_cost, "greedy never improved: {best} vs start {start_cost}");
        assert!(
            best <= grid.ranked[0].cost.epoch_s * 1.05,
            "greedy optimum {best} too far from grid {}",
            grid.ranked[0].cost.epoch_s
        );
        // Determinism.
        let again = GreedyDescent::default().search(&space, &oracle, 5);
        assert_eq!(again.ranked[0].candidate, greedy.ranked[0].candidate);
    }

    #[test]
    fn halving_converges_to_the_grid_winner_and_subsamples_deterministically() {
        let (scen, space) = setup();
        let oracle = CostOracle::new(&scen, None);
        let grid = ExhaustiveGrid.search(&space, &oracle, 0);
        let mut halving = SuccessiveHalving::default();
        let out = halving.search(&space, &oracle, 7);
        // Every candidate is scored once per rung it survives; the final
        // winner is scored at full fidelity and matches the grid's.
        assert_eq!(out.ranked[0].candidate, grid.ranked[0].candidate);
        assert_eq!(out.ranked.len(), space.len(), "eliminated candidates retained");
        // Seeded subsampling: same seed ⇒ same cohort ⇒ same result;
        // the sample bounds the cohort.
        let mk = || SuccessiveHalving {
            sample: Some(10),
            ..SuccessiveHalving::default()
        };
        let a = mk().search(&space, &oracle, 42);
        let b = mk().search(&space, &oracle, 42);
        assert_eq!(a.ranked.len(), 10);
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.cost.epoch_s.to_bits(), y.cost.epoch_s.to_bits());
        }
        assert!(a.evaluated <= 10 * 3);
    }

    #[test]
    fn halving_measured_promotion_reorders_survivors() {
        let (scen, space) = setup();
        let oracle = CostOracle::new(&scen, None);
        // A probe that inverts the sim's preference among the promoted:
        // the sim-best candidate "measures" slow.
        let sim_best = ExhaustiveGrid.search(&space, &oracle, 0).ranked[0]
            .candidate
            .clone();
        let mut calls = 0usize;
        let mut halving = SuccessiveHalving {
            promote: 2,
            measure: Some(Box::new(|c: &Candidate| {
                calls += 1;
                Ok(if c == &sim_best { 9.0 } else { 1.0 })
            })),
            ..SuccessiveHalving::default()
        };
        let out = halving.search(&space, &oracle, 7);
        drop(halving);
        assert_eq!(calls, 2, "exactly the promoted survivors are measured");
        assert_ne!(out.ranked[0].candidate, sim_best, "measurement overrode the sim rank");
        assert_eq!(out.ranked[0].measured_step_s, Some(1.0));
        // Strategy name advertises the measured leg.
        let named = SuccessiveHalving {
            measure: Some(Box::new(|_: &Candidate| Ok(0.0))),
            sample: Some(5),
            ..SuccessiveHalving::default()
        };
        assert_eq!(named.name(), "halving:eta=2,rungs=3,sample=5,measured");
    }
}
