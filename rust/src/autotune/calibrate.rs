//! Measured calibration of the cost oracle: fit the netsim constants
//! that are machine-dependent — per-runtime launch overhead, a compute
//! scale, and a link-bandwidth scale — from a handful of *measured* probe
//! steps, so the simulator ranks candidates for the machine the tuner is
//! actually running on.
//!
//! What gets fitted, and from what:
//!
//! * `spawn_per_thread_s` / `pool_dispatch_per_thread_s` — the per-thread
//!   launch cost of the scoped and pooled runtimes, from the
//!   `StepRecord::spawn_or_dispatch_us` trace of short real training runs
//!   (the measured twin of [`crate::netsim::SPAWN_PER_THREAD_S`] /
//!   [`crate::netsim::POOL_DISPATCH_PER_THREAD_S`]). Launch-half only,
//!   like the trace field itself — a lower bound, which is fine for
//!   *ranking* runtimes.
//! * `compute_scale` — measured serial step wall-clock over the probe's
//!   modelled compute time, where the probe model is scaled from the
//!   scenario profile by parameter count (a crude first-order fit: the
//!   scenario's t1 is multiplied by this host-vs-V100 factor).
//! * `bandwidth_scale` — a timed in-process ring all-reduce gives this
//!   host's achievable bytes/second for collective traffic; the scale is
//!   that throughput over the scenario link's modelled effective
//!   bandwidth. The probe's link-byte count comes from the same
//!   [`ring_allreduce_link_bytes`] formula the cost model prices, so the
//!   two stay reconciled — including when the payload has been shrunk by
//!   the wire codec.
//! * `wire_pack_per_elem_s` — a timed encode+decode round trip of a
//!   packed sparse payload gives this host's codec CPU cost per element
//!   (the measured twin of [`crate::netsim::WIRE_PACK_PER_ELEM_S`]); the
//!   oracle charges `2·k·const` into the comm span of `wire = packed`
//!   candidates.
//!
//! Calibration is measurement: it is **not deterministic** across runs or
//! machines, which is exactly its purpose. The tuner therefore keeps it
//! opt-in (`sparkv tune --calibrate N`), records the fitted constants in
//! the plan artifact, and the golden/determinism suites run uncalibrated.

use crate::collectives::{Collectives, SerialCollectives};
use crate::config::{Parallelism, TrainConfig};
use crate::data::GaussianMixture;
use crate::models::{Model, NativeMlp};
use crate::netsim::{
    ring_allreduce_link_bytes, POOL_DISPATCH_PER_THREAD_S, SPAWN_PER_THREAD_S,
    WIRE_PACK_PER_ELEM_S,
};
use crate::tensor::wire::{WireCodec, WireScratch};
use crate::tensor::SparseVec;
use crate::util::json::Json;

use super::space::TuneScenario;

/// Fitted model constants (see the module docs for the fit).
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Measured per-thread launch cost of `threads:N` (seconds).
    pub spawn_per_thread_s: f64,
    /// Measured per-thread dispatch cost of `pool:N` (seconds).
    pub pool_dispatch_per_thread_s: f64,
    /// Host-vs-modelled compute factor applied to the scenario's t1.
    pub compute_scale: f64,
    /// Host-vs-modelled link bandwidth factor applied to the scenario's
    /// links.
    pub bandwidth_scale: f64,
    /// Measured wire-codec CPU cost per sparse element (seconds); the
    /// oracle charges `2·k` of these (encode + decode) for packed
    /// candidates.
    pub wire_pack_per_elem_s: f64,
    /// Probe length the constants were fitted from.
    pub probe_steps: usize,
}

impl Calibration {
    /// The identity calibration: reproduces the uncalibrated oracle
    /// exactly (stock netsim constants, unit scales).
    pub fn identity() -> Calibration {
        Calibration {
            spawn_per_thread_s: SPAWN_PER_THREAD_S,
            pool_dispatch_per_thread_s: POOL_DISPATCH_PER_THREAD_S,
            compute_scale: 1.0,
            bandwidth_scale: 1.0,
            wire_pack_per_elem_s: WIRE_PACK_PER_ELEM_S,
            probe_steps: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("spawn_per_thread_s", Json::from(self.spawn_per_thread_s))
            .set(
                "pool_dispatch_per_thread_s",
                Json::from(self.pool_dispatch_per_thread_s),
            )
            .set("compute_scale", Json::from(self.compute_scale))
            .set("bandwidth_scale", Json::from(self.bandwidth_scale))
            .set("wire_pack_per_elem_s", Json::from(self.wire_pack_per_elem_s))
            .set("probe_steps", Json::from(self.probe_steps));
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Calibration> {
        let num = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("calibration: missing numeric field '{key}'"))
        };
        Ok(Calibration {
            spawn_per_thread_s: num("spawn_per_thread_s")?,
            pool_dispatch_per_thread_s: num("pool_dispatch_per_thread_s")?,
            compute_scale: num("compute_scale")?,
            bandwidth_scale: num("bandwidth_scale")?,
            // Plans calibrated before the wire axis carry no key: they
            // fall back to the stock codec constant.
            wire_pack_per_elem_s: j
                .get("wire_pack_per_elem_s")
                .and_then(Json::as_f64)
                .unwrap_or(WIRE_PACK_PER_ELEM_S),
            probe_steps: num("probe_steps")? as usize,
        })
    }

    /// Every constant finite and positive (scales strictly so), so a
    /// degenerate measurement can never zero out a whole cost term.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, v) in [
            ("spawn_per_thread_s", self.spawn_per_thread_s),
            ("pool_dispatch_per_thread_s", self.pool_dispatch_per_thread_s),
            ("compute_scale", self.compute_scale),
            ("bandwidth_scale", self.bandwidth_scale),
            ("wire_pack_per_elem_s", self.wire_pack_per_elem_s),
        ] {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "calibration {name} must be finite and > 0, got {v}"
            );
        }
        Ok(())
    }
}

/// Runs the measured probes and fits a [`Calibration`]. The probe is a
/// tiny native-MLP training job — large enough to exercise every
/// runtime's dispatch path, small enough that `--calibrate 8` costs well
/// under a second.
#[derive(Debug, Clone)]
pub struct Calibrator {
    /// Training steps per runtime probe (≥ 1; more steps average out
    /// scheduler noise).
    pub probe_steps: usize,
    /// Simulated workers in the probe runs.
    pub workers: usize,
    /// Thread budget for the threads/pool probes.
    pub threads: usize,
}

impl Default for Calibrator {
    fn default() -> Self {
        Calibrator {
            probe_steps: 8,
            workers: 4,
            threads: 4,
        }
    }
}

impl Calibrator {
    fn probe_cfg(&self, parallelism: Parallelism) -> TrainConfig {
        TrainConfig {
            workers: self.workers.max(1),
            steps: self.probe_steps.max(1),
            eval_every: 0,
            parallelism,
            ..TrainConfig::default()
        }
    }

    /// Run the probes and fit. Measurement floors guard against
    /// zero-resolution clocks: a constant that measures as 0 falls back
    /// to the stock netsim value rather than telling the oracle that a
    /// runtime is free.
    pub fn run(&self, scenario: &TuneScenario) -> anyhow::Result<Calibration> {
        let data = GaussianMixture::new(16, 4, 2.5, 1.0, 17);
        let probe_layers = [16usize, 64, 32, 4];
        let n = self.threads.max(1);

        let run_probe = |parallelism: Parallelism| -> anyhow::Result<(f64, f64)> {
            let mut model = NativeMlp::new(&probe_layers);
            let out = crate::coordinator::train(self.probe_cfg(parallelism), &mut model, &data)?;
            Ok((
                out.metrics.step_time.mean(),
                out.metrics.mean_spawn_or_dispatch_us() * 1e-6,
            ))
        };

        let (serial_step_s, _) = run_probe(Parallelism::Serial)?;
        let (_, spawn_s) = run_probe(Parallelism::Threads(n))?;
        let (_, dispatch_s) = run_probe(Parallelism::Pool(n))?;
        let launch_n = n.min(self.workers.max(1)).max(1) as f64;
        let spawn_per_thread_s = if spawn_s > 0.0 {
            spawn_s / launch_n
        } else {
            SPAWN_PER_THREAD_S
        };
        let pool_dispatch_per_thread_s = if dispatch_s > 0.0 {
            dispatch_s / launch_n
        } else {
            POOL_DISPATCH_PER_THREAD_S
        };

        // Compute scale: measured serial step wall over the probe's
        // modelled compute (scenario t1 scaled down by parameter count).
        // The serial probe steps its P workers *sequentially* while the
        // simulated cluster computes them in parallel (netsim charges t1
        // once per iteration), so the modelled probe wall is P × one
        // worker's compute — without that factor the fitted scale would
        // be inflated ~P× and over-weight compute in the ranking.
        let probe_model = NativeMlp::new(&probe_layers);
        let d_probe = Model::layout(&probe_model).total().max(1) as f64;
        let modelled_probe_s = scenario.model.t1_compute
            * (d_probe / scenario.model.params.max(1) as f64)
            * self.workers.max(1) as f64;
        let compute_scale = if serial_step_s > 0.0 && modelled_probe_s > 0.0 {
            serial_step_s / modelled_probe_s
        } else {
            1.0
        };

        // Bandwidth scale: time an in-process ring all-reduce and compare
        // this host's achieved bytes/s to the scenario link model. The
        // ring moves 2(P−1)·(m/P) bytes over the modelled bottleneck.
        let p = self.workers.max(2);
        let elems = 1usize << 16;
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|w| (0..elems).map(|i| (w * elems + i) as f32 * 1e-6).collect())
            .collect();
        let engine = SerialCollectives;
        let t0 = std::time::Instant::now();
        let reps = 4usize;
        for _ in 0..reps {
            std::hint::black_box(engine.ring_allreduce_avg(std::hint::black_box(&inputs)));
        }
        let elapsed = t0.elapsed().as_secs_f64() / reps as f64;
        let bytes_moved = ring_allreduce_link_bytes(p, elems as u64 * 4);
        let modelled_bps = scenario.topo.ring_bottleneck().effective_bandwidth();
        let bandwidth_scale = if elapsed > 0.0 && modelled_bps > 0.0 {
            (bytes_moved / elapsed) / modelled_bps
        } else {
            1.0
        };

        // Wire-codec probe: time a packed encode+decode round trip of a
        // realistic top-k payload (clustered-ish stride-3 indices over a
        // 1M-element domain) and spread the wall over the 2·k element
        // touches the oracle charges. Zero-resolution clocks fall back to
        // the stock constant.
        let k_probe = 1usize << 14;
        let pairs: Vec<(u32, f32)> = (0..k_probe)
            .map(|i| ((i * 3) as u32, (i as f32).sin()))
            .collect();
        let mut probe_vec = SparseVec::from_pairs(1 << 20, pairs);
        let mut scratch = WireScratch::default();
        let t0 = std::time::Instant::now();
        let wire_reps = 8usize;
        for _ in 0..wire_reps {
            std::hint::black_box(
                WireCodec::Packed.roundtrip(std::hint::black_box(&mut probe_vec), &mut scratch),
            );
        }
        let wire_elapsed = t0.elapsed().as_secs_f64() / wire_reps as f64;
        let wire_pack_per_elem_s = if wire_elapsed > 0.0 {
            wire_elapsed / (2.0 * k_probe as f64)
        } else {
            WIRE_PACK_PER_ELEM_S
        };

        let cal = Calibration {
            spawn_per_thread_s,
            pool_dispatch_per_thread_s,
            compute_scale,
            bandwidth_scale,
            wire_pack_per_elem_s,
            probe_steps: self.probe_steps.max(1),
        };
        cal.validate()?;
        Ok(cal)
    }

    /// Fit a [`Calibration`] from a recorded span trace instead of live
    /// probe runs (`sparkv tune --calibrate-from trace.json`). Only the
    /// phases a trace actually measures are fitted — the compute and
    /// bandwidth scales; the launch and wire-codec constants stay at
    /// their stock netsim values, because spans record *phase* walls,
    /// not launch halves or codec CPU. `probe_steps` records how many
    /// traced steps the fit averaged over.
    pub fn fit_from_trace(
        trace: &crate::trace::TraceData,
        scenario: &TuneScenario,
    ) -> anyhow::Result<Calibration> {
        let measured = crate::trace::report::fold(trace)?;
        let mean = measured.mean();
        let steps = measured.steps.len();
        let meta = &trace.meta;
        let d = meta.d.max(1);

        // Compute scale: the fold's compute phase is the critical-path
        // per-worker forward/backward wall (max over worker tracks) —
        // the measured twin of the t1 the oracle charges once per
        // iteration, so no worker factor here (unlike the serial probe
        // in [`Calibrator::run`], which steps workers sequentially).
        let modelled_compute_s =
            scenario.model.t1_compute * (d as f64 / scenario.model.params.max(1) as f64);
        let compute_scale = if mean.compute > 0.0 && modelled_compute_s > 0.0 {
            mean.compute / modelled_compute_s
        } else {
            1.0
        };

        // Bandwidth scale: achieved bytes/s of the traced collective
        // phase over the scenario link model, with the payload priced
        // the way the oracle prices it — the dense gradient for
        // `op = dense`, the top-k (index, value) pairs otherwise.
        let payload_bytes = if meta.op == "dense" {
            d as u64 * 4
        } else {
            (((meta.k_ratio * d as f64).ceil() as u64).max(1)) * 8
        };
        let p = meta.workers.max(2);
        let bytes_moved = ring_allreduce_link_bytes(p, payload_bytes);
        let modelled_bps = scenario.topo.ring_bottleneck().effective_bandwidth();
        let bandwidth_scale = if mean.comm > 0.0 && modelled_bps > 0.0 {
            (bytes_moved / mean.comm) / modelled_bps
        } else {
            1.0
        };

        let cal = Calibration {
            spawn_per_thread_s: SPAWN_PER_THREAD_S,
            pool_dispatch_per_thread_s: POOL_DISPATCH_PER_THREAD_S,
            compute_scale,
            bandwidth_scale,
            wire_pack_per_elem_s: WIRE_PACK_PER_ELEM_S,
            probe_steps: steps,
        };
        cal.validate()?;
        Ok(cal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_calibration_matches_stock_oracle() {
        use super::super::oracle::CostOracle;
        use super::super::space::{Candidate, TuneScenario};
        let mut scen = TuneScenario::default_16gpu();
        scen.steps_per_epoch = 4;
        let cal = Calibration::identity();
        cal.validate().unwrap();
        let stock = CostOracle::new(&scen, None);
        let ident = CostOracle::new(&scen, Some(&cal));
        let mut c = Candidate::baseline();
        c.parallelism = Parallelism::Threads(4);
        assert_eq!(
            stock.predict(&c).epoch_s.to_bits(),
            ident.predict(&c).epoch_s.to_bits(),
            "identity calibration must reproduce the stock oracle bit-for-bit"
        );
    }

    #[test]
    fn calibration_json_round_trips_and_validates() {
        let cal = Calibration {
            spawn_per_thread_s: 2.5e-5,
            pool_dispatch_per_thread_s: 1.1e-6,
            compute_scale: 3.5,
            bandwidth_scale: 12.0,
            wire_pack_per_elem_s: 2.0e-9,
            probe_steps: 8,
        };
        let j = Json::parse(&cal.to_json().to_string()).unwrap();
        assert_eq!(Calibration::from_json(&j).unwrap(), cal);
        let mut bad = cal.clone();
        bad.compute_scale = 0.0;
        assert!(bad.validate().is_err());
        bad.compute_scale = f64::NAN;
        assert!(bad.validate().is_err());
        bad.compute_scale = 3.5;
        bad.wire_pack_per_elem_s = 0.0;
        assert!(bad.validate().is_err());
        // A calibration written before the wire axis (no codec key)
        // parses with the stock constant.
        let mut legacy = Json::obj();
        legacy
            .set("spawn_per_thread_s", Json::from(2.5e-5))
            .set("pool_dispatch_per_thread_s", Json::from(1.1e-6))
            .set("compute_scale", Json::from(3.5))
            .set("bandwidth_scale", Json::from(12.0))
            .set("probe_steps", Json::from(8usize));
        assert_eq!(
            Calibration::from_json(&legacy).unwrap().wire_pack_per_elem_s,
            WIRE_PACK_PER_ELEM_S
        );
    }

    #[test]
    fn fit_from_trace_scales_compute_and_bandwidth_only() {
        use crate::trace::{worker_track, Phase, Span, TraceData, TraceMeta, COORDINATOR_TRACK};
        let meta = TraceMeta {
            workers: 2,
            d: 1000,
            steps: 2,
            k_ratio: 0.01,
            op: "topk".to_string(),
            parallelism: "serial".to_string(),
            buckets: 1,
            exchange: "allgather".to_string(),
            wire: "raw".to_string(),
            select: "sort".to_string(),
        };
        let mut spans = Vec::new();
        for step in 0u32..2 {
            let base = step as f64 * 1000.0;
            spans.push(Span {
                track: COORDINATOR_TRACK,
                phase: Phase::Step,
                step,
                bucket: -1,
                t0_us: base,
                t1_us: base + 500.0,
            });
            spans.push(Span {
                track: COORDINATOR_TRACK,
                phase: Phase::Collective,
                step,
                bucket: -1,
                t0_us: base + 300.0,
                t1_us: base + 400.0,
            });
            for rank in 0..2usize {
                spans.push(Span {
                    track: worker_track(rank),
                    phase: Phase::Compute,
                    step,
                    bucket: -1,
                    t0_us: base,
                    t1_us: base + 200.0,
                });
            }
        }
        let trace = TraceData { meta, spans, dropped: 0 };
        let scen = TuneScenario::default_16gpu();
        let cal = Calibrator::fit_from_trace(&trace, &scen).unwrap();
        cal.validate().unwrap();
        assert_eq!(cal.probe_steps, 2, "probe_steps records the traced step count");
        // Unfittable constants stay stock so the oracle's launch/codec
        // terms are unchanged by a trace-sourced calibration.
        assert_eq!(cal.spawn_per_thread_s, SPAWN_PER_THREAD_S);
        assert_eq!(cal.pool_dispatch_per_thread_s, POOL_DISPATCH_PER_THREAD_S);
        assert_eq!(cal.wire_pack_per_elem_s, WIRE_PACK_PER_ELEM_S);
        assert!(cal.compute_scale > 0.0 && cal.compute_scale.is_finite());
        assert!(cal.bandwidth_scale > 0.0 && cal.bandwidth_scale.is_finite());
        // A trace with no step spans is malformed, not a unit fit.
        let empty = TraceData {
            meta: trace.meta.clone(),
            spans: Vec::new(),
            dropped: 0,
        };
        assert!(Calibrator::fit_from_trace(&empty, &scen).is_err());
    }

    #[test]
    fn calibrator_fits_finite_positive_constants() {
        let scen = TuneScenario::default_16gpu();
        let cal = Calibrator {
            probe_steps: 3,
            workers: 4,
            threads: 2,
        }
        .run(&scen)
        .unwrap();
        cal.validate().unwrap();
        assert_eq!(cal.probe_steps, 3);
        // Measured constants are real measurements: positive and finite
        // (asserting machine-specific magnitudes would be flaky).
        assert!(cal.spawn_per_thread_s > 0.0);
        assert!(cal.pool_dispatch_per_thread_s > 0.0);
        assert!(cal.compute_scale > 0.0 && cal.bandwidth_scale > 0.0);
        assert!(cal.wire_pack_per_elem_s > 0.0 && cal.wire_pack_per_elem_s.is_finite());
    }
}
