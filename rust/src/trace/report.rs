//! Fold a recorded trace into a measured [`IterationBreakdown`] and diff
//! it against the netsim prediction for the same configuration — the
//! measurement half of ROADMAP item 5's drift detector, surfaced as
//! `sparkv report`.
//!
//! ## Methodology
//!
//! The measured fold mirrors the netsim's phase semantics:
//!
//! * `compute` — per step, the **max** over worker tracks of that
//!   worker's summed `sample` + `compute` span time (synchronous SGD's
//!   barrier waits for the slowest worker; the netsim folds sampling
//!   into its compute term because it does not model a data pipeline).
//! * `select`  — per step, the max over worker tracks of summed
//!   `select` + `ef_apply` time (selection, encode, and the residual
//!   update are all operator-side CPU the netsim prices as selection).
//! * `comm`    — per step, the summed duration of the coordinator-track
//!   `collective` spans (the call-site wall of every engine call — the
//!   same number `StepRecord::comm_us` records).
//! * `total`   — the coordinator `step` umbrella span's duration.
//!
//! The prediction is the [`Simulator`] run on a [`SimConfig`] rebuilt
//! from the trace's embedded metadata. An in-process trace measures
//! *this host*, not the modelled cluster, so absolute magnitudes are
//! incomparable; the report therefore fits one multiplicative scale per
//! phase on the **first half** of the traced steps and evaluates drift
//! on the full-trace means. Drift then measures *nonstationarity* —
//! whether the run's phase balance wandered away from what a model
//! calibrated on its opening steps would predict — which is exactly the
//! signal an online re-tuning loop needs. The scaled predicted total is
//! recomposed as the scaled serialized sum shrunk by the simulator's
//! own overlap factor `total / (compute + select + comm)` (the bucketed
//! pipeline hides communication inside selection; the factor is 1 on
//! monolithic timelines).
//!
//! Per-phase drift above [`PHASE_DRIFT_THRESHOLD`] (50%) flags the row;
//! total drift above [`TOTAL_DRIFT_THRESHOLD`] (100%) flags the
//! structural row. Flags are advisory — `sparkv report` exits non-zero
//! only for *malformed* traces (or under `--strict`).

use anyhow::{anyhow, ensure};

use super::{Phase, TraceData, TraceMeta, RING_TRACK_BASE};
use crate::compress::OpKind;
use crate::config::{Exchange, Parallelism};
use crate::netsim::{
    runtime_overhead_s, ComputeProfile, IterationBreakdown, LinkSpec, SimConfig, Simulator,
    Topology,
};
use crate::tensor::wire::WireCodec;

/// Per-phase drift above this fraction flags the phase row (documented
/// acceptance bound for the default scenario).
pub const PHASE_DRIFT_THRESHOLD: f64 = 0.5;

/// Total-time drift above this fraction flags the structural row — a
/// looser bound, since `total` also absorbs overlap-model error.
pub const TOTAL_DRIFT_THRESHOLD: f64 = 1.0;

/// Host compute speed assumed when rebuilding the predicted model from a
/// trace: the Table 2 V100 per-parameter fwd+bwd rate. Absolute values
/// are irrelevant to the drift report (the per-phase fit absorbs them);
/// this just keeps the base prediction deterministic.
fn per_param_compute_s() -> f64 {
    let r = ComputeProfile::by_name("resnet50").expect("catalog model");
    r.t1_compute / r.params.max(1) as f64
}

/// One step's measured phase times (seconds), folded from its spans.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepPhases {
    pub step: u32,
    pub compute_s: f64,
    pub select_s: f64,
    pub comm_s: f64,
    pub total_s: f64,
}

/// The measured fold of a whole trace: one [`StepPhases`] per traced
/// step, in step order.
#[derive(Debug, Clone, PartialEq)]
pub struct Measured {
    pub steps: Vec<StepPhases>,
}

impl Measured {
    /// Mean phase times over a step range (used for the first-half fit
    /// and the full-trace evaluation).
    fn mean_over(&self, range: std::ops::Range<usize>) -> IterationBreakdown {
        let slice = &self.steps[range];
        let n = slice.len().max(1) as f64;
        let (mut c, mut s, mut m, mut t) = (0.0, 0.0, 0.0, 0.0);
        for p in slice {
            c += p.compute_s;
            s += p.select_s;
            m += p.comm_s;
            t += p.total_s;
        }
        let (c, s, m, t) = (c / n, s / n, m / n, t / n);
        IterationBreakdown {
            compute: c,
            select: s,
            comm: m,
            max_skew: 0.0,
            total: t,
            overlap_saved: (c + s + m - t).max(0.0),
        }
    }

    /// Full-trace mean breakdown.
    pub fn mean(&self) -> IterationBreakdown {
        self.mean_over(0..self.steps.len())
    }
}

/// Fold a trace's spans into per-step measured phase times. Errors on
/// structurally broken traces: no coordinator `step` spans, or a step
/// span with a non-positive duration.
pub fn fold(trace: &TraceData) -> anyhow::Result<Measured> {
    let mut steps: Vec<StepPhases> = Vec::new();
    // step → index into `steps`, resolved via the coordinator umbrellas.
    for s in trace.spans.iter().filter(|s| s.phase == Phase::Step) {
        ensure!(
            s.dur_us() > 0.0,
            "trace step {} has a non-positive step span ({} µs)",
            s.step,
            s.dur_us()
        );
        steps.push(StepPhases {
            step: s.step,
            compute_s: 0.0,
            select_s: 0.0,
            comm_s: 0.0,
            total_s: s.dur_us() * 1e-6,
        });
    }
    ensure!(
        !steps.is_empty(),
        "trace has no coordinator step spans — was it recorded with trace = spans?"
    );
    steps.sort_by_key(|p| p.step);
    steps.dedup_by_key(|p| p.step);
    let idx_of = |step: u32| steps.binary_search_by_key(&step, |p| p.step).ok();

    // Per (worker track, step) sums; the barrier max is taken per step.
    use std::collections::BTreeMap;
    let mut worker_compute: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    let mut worker_select: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    for s in &trace.spans {
        let dur_s = s.dur_us() * 1e-6;
        if s.track == super::COORDINATOR_TRACK {
            if s.phase == Phase::Collective {
                if let Some(i) = idx_of(s.step) {
                    steps[i].comm_s += dur_s;
                }
            }
        } else if s.track < RING_TRACK_BASE {
            let key = (s.track, s.step);
            match s.phase {
                Phase::Sample | Phase::Compute => {
                    *worker_compute.entry(key).or_insert(0.0) += dur_s;
                }
                Phase::Select | Phase::EfApply => {
                    *worker_select.entry(key).or_insert(0.0) += dur_s;
                }
                _ => {}
            }
        }
        // Ring-seat spans time the same collectives the coordinator
        // already timed at the call site; they stay visualization-only.
    }
    for ((_, step), v) in worker_compute {
        if let Some(i) = idx_of(step) {
            steps[i].compute_s = steps[i].compute_s.max(v);
        }
    }
    for ((_, step), v) in worker_select {
        if let Some(i) = idx_of(step) {
            steps[i].select_s = steps[i].select_s.max(v);
        }
    }
    Ok(Measured { steps })
}

/// Rebuild the netsim configuration a trace's metadata describes: a
/// single-node cluster of `workers` PCIe-attached ranks (the in-process
/// analog), the traced model's parameter count at the catalog
/// per-parameter compute rate, and the traced op / density / bucket /
/// exchange / wire axes. Unknown metadata strings are hard errors (a
/// malformed trace must not silently fold into a wrong prediction).
pub fn sim_config(meta: &TraceMeta) -> anyhow::Result<SimConfig> {
    ensure!(meta.workers >= 1, "trace metadata: workers must be >= 1");
    ensure!(meta.d >= 1, "trace metadata: d must be >= 1");
    let op = OpKind::parse(&meta.op)?;
    let parallelism = Parallelism::parse(&meta.parallelism)?;
    let exchange = Exchange::parse(&meta.exchange)?;
    let wire = WireCodec::parse(&meta.wire)?;
    ensure!(
        meta.k_ratio > 0.0 && meta.k_ratio <= 1.0,
        "trace metadata: k_ratio {} outside (0, 1]",
        meta.k_ratio
    );
    let topo = Topology::new(1, meta.workers, LinkSpec::pcie3_x16(), LinkSpec::ethernet_10g());
    let model = ComputeProfile::new("traced", meta.d as u64, 0.0);
    let mut cfg = SimConfig::table2(model, op);
    cfg.topo = topo;
    cfg.model.t1_compute = per_param_compute_s() * meta.d as f64;
    cfg.k_ratio = meta.k_ratio;
    cfg.buckets = meta.buckets.max(1);
    cfg.host_overhead_s = runtime_overhead_s(parallelism, meta.workers);
    cfg.exchange = if op == OpKind::Dense { Exchange::DenseRing } else { exchange };
    cfg.wire = wire;
    Ok(cfg)
}

/// One row of the measured-vs-predicted table.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftRow {
    pub phase: &'static str,
    /// Full-trace measured mean (seconds).
    pub measured_s: f64,
    /// First-half-scaled prediction (seconds).
    pub predicted_s: f64,
    /// The per-phase scale fitted on the first half.
    pub scale: f64,
    /// `|measured − predicted| / predicted` (∞ when the model predicts
    /// 0 but the trace measured time — a structural mismatch).
    pub drift: f64,
    pub threshold: f64,
    pub flagged: bool,
}

/// The complete drift report `sparkv report` renders.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftReport {
    pub rows: Vec<DriftRow>,
    /// Steps the scales were fitted on (the first half).
    pub fit_steps: usize,
    /// Steps the drift was evaluated on (all of them).
    pub eval_steps: usize,
    /// Spans lost to recorder overflow (non-zero taints the fold).
    pub dropped: u64,
}

impl DriftReport {
    /// True when no phase exceeded its drift threshold.
    pub fn ok(&self) -> bool {
        self.rows.iter().all(|r| !r.flagged)
    }

    /// Render the aligned text table (phase, measured, predicted, fitted
    /// scale, drift, flag).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<10} {:>13} {:>13} {:>10} {:>9}  flag\n",
            "phase", "measured(ms)", "predicted(ms)", "scale", "drift"
        ));
        for r in &self.rows {
            let drift = if r.drift.is_finite() {
                format!("{:+.1}%", r.drift * 100.0)
            } else {
                "inf".to_string()
            };
            out.push_str(&format!(
                "{:<10} {:>13.3} {:>13.3} {:>10.3} {:>9}  {}\n",
                r.phase,
                r.measured_s * 1e3,
                r.predicted_s * 1e3,
                r.scale,
                drift,
                if r.flagged {
                    format!("DRIFT>{:.0}%", r.threshold * 100.0)
                } else {
                    "ok".to_string()
                }
            ));
        }
        out.push_str(&format!(
            "fit: first {} steps · eval: all {} steps · dropped spans: {}\n",
            self.fit_steps, self.eval_steps, self.dropped
        ));
        out
    }
}

fn fit_scale(measured: f64, predicted: f64) -> f64 {
    if predicted > 0.0 && measured > 0.0 {
        measured / predicted
    } else {
        1.0
    }
}

fn drift_of(measured: f64, predicted: f64) -> f64 {
    if predicted > 0.0 {
        (measured - predicted).abs() / predicted
    } else if measured > 0.0 {
        f64::INFINITY
    } else {
        0.0
    }
}

/// Build the measured-vs-predicted drift report for a trace. Errors only
/// on malformed input (unfoldable spans, unparsable metadata); drift
/// beyond the thresholds flags rows but still reports.
pub fn drift_report(trace: &TraceData) -> anyhow::Result<DriftReport> {
    let measured = fold(trace)?;
    let cfg = sim_config(&trace.meta)?;
    let predicted = Simulator::new(cfg).iteration();

    let n = measured.steps.len();
    let fit_n = n.div_ceil(2);
    let fit = measured.mean_over(0..fit_n);
    let eval = measured.mean();

    let s_compute = fit_scale(fit.compute, predicted.compute);
    let s_select = fit_scale(fit.select, predicted.select);
    let s_comm = fit_scale(fit.comm, predicted.comm);
    // The simulator's own overlap factor, applied to the scaled
    // serialized sum (1.0 on monolithic timelines, < 1 when the bucketed
    // pipeline hides communication).
    let serialized = predicted.compute + predicted.select + predicted.comm;
    let overlap_factor = if serialized > 0.0 {
        (predicted.total / serialized).min(1.0)
    } else {
        1.0
    };
    let p_compute = s_compute * predicted.compute;
    let p_select = s_select * predicted.select;
    let p_comm = s_comm * predicted.comm;
    let p_total = (p_compute + p_select + p_comm) * overlap_factor;

    let row = |phase: &'static str, m: f64, p: f64, scale: f64, threshold: f64| {
        let drift = drift_of(m, p);
        DriftRow {
            phase,
            measured_s: m,
            predicted_s: p,
            scale,
            drift,
            threshold,
            flagged: !(drift <= threshold),
        }
    };
    let rows = vec![
        row("compute", eval.compute, p_compute, s_compute, PHASE_DRIFT_THRESHOLD),
        row("select", eval.select, p_select, s_select, PHASE_DRIFT_THRESHOLD),
        row("comm", eval.comm, p_comm, s_comm, PHASE_DRIFT_THRESHOLD),
        row(
            "total",
            eval.total,
            p_total,
            overlap_factor,
            TOTAL_DRIFT_THRESHOLD,
        ),
    ];
    Ok(DriftReport {
        rows,
        fit_steps: fit_n,
        eval_steps: n,
        dropped: trace.dropped,
    })
}

#[cfg(test)]
mod tests {
    use super::super::tests::test_meta;
    use super::super::{ring_track, worker_track, Span, COORDINATOR_TRACK};
    use super::*;

    /// A synthetic 4-step trace with a stationary phase balance:
    /// per step, 2 workers compute 100 µs + sample 10 µs, select
    /// 20 µs + ef_apply 5 µs, and the coordinator times two 15 µs
    /// collectives inside a 160 µs step.
    fn stationary_trace() -> TraceData {
        let mut spans = Vec::new();
        for step in 0..4u32 {
            let base = step as f64 * 1000.0;
            spans.push(Span {
                track: COORDINATOR_TRACK,
                phase: Phase::Step,
                step,
                bucket: -1,
                t0_us: base,
                t1_us: base + 160.0,
            });
            for b in 0..2 {
                spans.push(Span {
                    track: COORDINATOR_TRACK,
                    phase: Phase::Collective,
                    step,
                    bucket: b,
                    t0_us: base + 120.0 + 16.0 * b as f64,
                    t1_us: base + 135.0 + 16.0 * b as f64,
                });
            }
            for w in 0..2 {
                let t = worker_track(w);
                spans.push(Span {
                    track: t,
                    phase: Phase::Sample,
                    step,
                    bucket: -1,
                    t0_us: base,
                    t1_us: base + 10.0,
                });
                spans.push(Span {
                    track: t,
                    phase: Phase::Compute,
                    step,
                    bucket: -1,
                    t0_us: base + 10.0,
                    t1_us: base + 110.0,
                });
                spans.push(Span {
                    track: t,
                    phase: Phase::Select,
                    step,
                    bucket: 0,
                    t0_us: base + 110.0,
                    t1_us: base + 130.0,
                });
                spans.push(Span {
                    track: t,
                    phase: Phase::EfApply,
                    step,
                    bucket: -1,
                    t0_us: base + 130.0,
                    t1_us: base + 135.0,
                });
            }
            // A ring-seat span: visualization-only, must not perturb the
            // fold.
            spans.push(Span {
                track: ring_track(0),
                phase: Phase::Collective,
                step,
                bucket: -1,
                t0_us: base + 120.0,
                t1_us: base + 150.0,
            });
        }
        let mut meta = test_meta();
        meta.workers = 2;
        meta.buckets = 2;
        TraceData {
            meta,
            spans,
            dropped: 0,
        }
    }

    #[test]
    fn fold_takes_barrier_max_and_coordinator_comm() {
        let m = fold(&stationary_trace()).unwrap();
        assert_eq!(m.steps.len(), 4);
        for p in &m.steps {
            assert!((p.compute_s - 110.0e-6).abs() < 1e-12, "{p:?}");
            assert!((p.select_s - 25.0e-6).abs() < 1e-12, "{p:?}");
            assert!((p.comm_s - 30.0e-6).abs() < 1e-12, "{p:?}");
            assert!((p.total_s - 160.0e-6).abs() < 1e-12, "{p:?}");
        }
        let mean = m.mean();
        assert!((mean.total - 160.0e-6).abs() < 1e-12);
        assert!(mean.overlap_saved > 0.0, "phases exceed the wall: overlap");
    }

    #[test]
    fn fold_rejects_spanless_traces() {
        let t = TraceData {
            meta: test_meta(),
            spans: Vec::new(),
            dropped: 0,
        };
        assert!(fold(&t).is_err());
    }

    #[test]
    fn stationary_trace_has_zero_phase_drift() {
        // Identical steps: the first-half fit predicts the full-trace
        // means exactly, so every phase row reads ~0 drift.
        let r = drift_report(&stationary_trace()).unwrap();
        assert!(r.ok(), "{}", r.render());
        for row in &r.rows {
            assert!(row.drift < 1e-9, "{row:?}");
        }
        assert_eq!(r.fit_steps, 2);
        assert_eq!(r.eval_steps, 4);
    }

    #[test]
    fn nonstationary_trace_flags_the_wandering_phase() {
        // Double the collective time in the second half: comm drifts by
        // ~50% against the first-half fit while compute stays put.
        let mut t = stationary_trace();
        for s in &mut t.spans {
            if s.track == COORDINATOR_TRACK && s.phase == Phase::Collective && s.step >= 2 {
                s.t1_us += 2.0 * s.dur_us();
            }
        }
        let r = drift_report(&t).unwrap();
        let comm = r.rows.iter().find(|r| r.phase == "comm").unwrap();
        let compute = r.rows.iter().find(|r| r.phase == "compute").unwrap();
        assert!(comm.drift > PHASE_DRIFT_THRESHOLD, "{}", r.render());
        assert!(comm.flagged);
        assert!(compute.drift < 1e-9 && !compute.flagged);
    }

    #[test]
    fn sim_config_rejects_malformed_metadata() {
        let mut meta = test_meta();
        meta.op = "mystery".into();
        assert!(sim_config(&meta).is_err());
        let mut meta = test_meta();
        meta.workers = 0;
        assert!(sim_config(&meta).is_err());
        let mut meta = test_meta();
        meta.k_ratio = 0.0;
        assert!(sim_config(&meta).is_err());
        assert!(sim_config(&test_meta()).is_ok());
    }

    #[test]
    fn report_renders_a_table() {
        let r = drift_report(&stationary_trace()).unwrap();
        let text = r.render();
        for needle in ["phase", "compute", "select", "comm", "total", "drift"] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
