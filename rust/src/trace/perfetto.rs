//! Chrome trace-event (Perfetto) JSON encoding of a [`TraceData`].
//!
//! The file is the standard `traceEvents` object form, loadable in
//! `ui.perfetto.dev` or `chrome://tracing`: every span is a complete
//! event (`"ph": "X"`) with µs timestamps, `pid` 0, and `tid` = track
//! (0 coordinator, `1..=P` workers, `1000+r` ring seats); thread-name
//! metadata events label the tracks. A top-level `sparkv` object carries
//! the run metadata (`TraceMeta`) that `sparkv report` folds against the
//! netsim prediction — Perfetto ignores unknown top-level keys, so the
//! same file serves both consumers.

use anyhow::{anyhow, bail, Context};

use super::{Phase, Span, TraceData, TraceMeta, COORDINATOR_TRACK, RING_TRACK_BASE};
use crate::util::json::Json;

/// Human label for a track id (thread-name metadata).
fn track_name(track: u32) -> String {
    if track == COORDINATOR_TRACK {
        "coordinator".to_string()
    } else if track >= RING_TRACK_BASE {
        format!("ring seat {}", track - RING_TRACK_BASE)
    } else {
        format!("worker {}", track - 1)
    }
}

/// Encode a trace as a Chrome trace-event JSON document.
pub fn to_json(trace: &TraceData) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(trace.spans.len() + 8);
    for track in trace.tracks() {
        let mut m = Json::obj();
        m.set("ph", "M".into());
        m.set("name", "thread_name".into());
        m.set("pid", 0usize.into());
        m.set("tid", (track as usize).into());
        let mut args = Json::obj();
        args.set("name", track_name(track).into());
        m.set("args", args);
        events.push(m);
    }
    for s in &trace.spans {
        let mut e = Json::obj();
        e.set("ph", "X".into());
        e.set("name", s.phase.name().into());
        e.set("pid", 0usize.into());
        e.set("tid", (s.track as usize).into());
        e.set("ts", s.t0_us.into());
        e.set("dur", s.dur_us().into());
        let mut args = Json::obj();
        args.set("step", (s.step as usize).into());
        if s.bucket >= 0 {
            args.set("bucket", (s.bucket as usize).into());
        }
        e.set("args", args);
        events.push(e);
    }
    let mut meta = Json::obj();
    meta.set("workers", trace.meta.workers.into());
    meta.set("d", trace.meta.d.into());
    meta.set("steps", trace.meta.steps.into());
    meta.set("k_ratio", trace.meta.k_ratio.into());
    meta.set("op", trace.meta.op.as_str().into());
    meta.set("parallelism", trace.meta.parallelism.as_str().into());
    meta.set("buckets", trace.meta.buckets.into());
    meta.set("exchange", trace.meta.exchange.as_str().into());
    meta.set("wire", trace.meta.wire.as_str().into());
    meta.set("select", trace.meta.select.as_str().into());
    meta.set("dropped", (trace.dropped as usize).into());

    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(events));
    root.set("displayTimeUnit", "ms".into());
    root.set("sparkv", meta);
    root
}

/// Decode (and validate) a Chrome trace-event document produced by
/// [`to_json`]. Every malformation — missing `traceEvents`, a span with
/// an unknown phase name, non-finite or negative timestamps, a missing
/// or incomplete `sparkv` metadata object — is a hard error, which is
/// what lets `sparkv report` exit non-zero on corrupt traces.
pub fn from_json(root: &Json) -> anyhow::Result<TraceData> {
    let obj = root.as_obj().ok_or_else(|| anyhow!("trace root is not an object"))?;
    let events = obj
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow!("trace has no traceEvents array"))?;
    let meta_obj = obj
        .get("sparkv")
        .and_then(|m| m.as_obj())
        .ok_or_else(|| anyhow!("trace has no sparkv metadata object"))?;

    let req_num = |key: &str| -> anyhow::Result<f64> {
        meta_obj
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("sparkv metadata missing numeric '{key}'"))
    };
    let req_str = |key: &str| -> anyhow::Result<String> {
        meta_obj
            .get(key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("sparkv metadata missing string '{key}'"))
    };
    let meta = TraceMeta {
        workers: req_num("workers")? as usize,
        d: req_num("d")? as usize,
        steps: req_num("steps")? as usize,
        k_ratio: req_num("k_ratio")?,
        op: req_str("op")?,
        parallelism: req_str("parallelism")?,
        buckets: req_num("buckets")? as usize,
        exchange: req_str("exchange")?,
        wire: req_str("wire")?,
        select: req_str("select")?,
    };
    let dropped = meta_obj.get("dropped").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;

    let mut spans = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        let e = ev.as_obj().ok_or_else(|| anyhow!("traceEvents[{i}] is not an object"))?;
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        match ph {
            "M" => continue,
            "X" => {}
            other => bail!("traceEvents[{i}]: unsupported event phase {other:?}"),
        }
        let name = e
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no name"))?;
        let phase = Phase::parse(name)
            .ok_or_else(|| anyhow!("traceEvents[{i}]: unknown span name {name:?}"))?;
        let ts = e
            .get("ts")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no ts"))?;
        let dur = e
            .get("dur")
            .and_then(|d| d.as_f64())
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no dur"))?;
        if !ts.is_finite() || !dur.is_finite() || dur < 0.0 {
            bail!("traceEvents[{i}]: bad timestamps ts={ts} dur={dur}");
        }
        let tid = e
            .get("tid")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no tid"))?;
        if tid < 0.0 || tid.fract() != 0.0 {
            bail!("traceEvents[{i}]: bad tid {tid}");
        }
        let args = e.get("args").and_then(|a| a.as_obj());
        let step = args
            .and_then(|a| a.get("step"))
            .and_then(|s| s.as_f64())
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no args.step"))? as u32;
        let bucket = args
            .and_then(|a| a.get("bucket"))
            .and_then(|b| b.as_f64())
            .map_or(-1, |b| b as i32);
        spans.push(Span {
            track: tid as u32,
            phase,
            step,
            bucket,
            t0_us: ts,
            t1_us: ts + dur,
        });
    }
    Ok(TraceData {
        meta,
        spans,
        dropped,
    })
}

/// Write a trace to `path` as Perfetto-loadable JSON.
pub fn write(path: &str, trace: &TraceData) -> anyhow::Result<()> {
    std::fs::write(path, to_json(trace).to_string())
        .with_context(|| format!("writing trace {path}"))
}

/// Load (and validate) a trace file written by [`write`].
pub fn load(path: &str) -> anyhow::Result<TraceData> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let json = Json::parse(&text).with_context(|| format!("parsing trace {path}"))?;
    from_json(&json).with_context(|| format!("validating trace {path}"))
}

#[cfg(test)]
mod tests {
    use super::super::tests::test_meta;
    use super::super::{ring_track, worker_track};
    use super::*;

    fn sample_trace() -> TraceData {
        let spans = vec![
            Span {
                track: COORDINATOR_TRACK,
                phase: Phase::Step,
                step: 0,
                bucket: -1,
                t0_us: 0.0,
                t1_us: 100.0,
            },
            Span {
                track: worker_track(1),
                phase: Phase::Select,
                step: 0,
                bucket: 2,
                t0_us: 10.0,
                t1_us: 30.0,
            },
            Span {
                track: ring_track(0),
                phase: Phase::Collective,
                step: 0,
                bucket: -1,
                t0_us: 40.0,
                t1_us: 55.0,
            },
        ];
        TraceData {
            meta: test_meta(),
            spans,
            dropped: 0,
        }
    }

    #[test]
    fn json_round_trips_exactly() {
        let t = sample_trace();
        let j = to_json(&t);
        let back = from_json(&j).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn writer_emits_track_names_and_metadata() {
        let j = to_json(&sample_trace());
        let text = j.to_string();
        assert!(text.contains("\"coordinator\""));
        assert!(text.contains("\"worker 1\""));
        assert!(text.contains("\"ring seat 0\""));
        assert!(text.contains("\"sparkv\""));
        assert!(text.contains("\"traceEvents\""));
        // Bucket-scoped spans carry the bucket arg; others omit it.
        assert!(text.contains("\"bucket\""));
    }

    #[test]
    fn malformed_traces_are_rejected() {
        // No traceEvents at all.
        assert!(from_json(&Json::parse("{}").unwrap()).is_err());
        // Events but no sparkv metadata.
        assert!(from_json(&Json::parse(r#"{"traceEvents":[]}"#).unwrap()).is_err());
        // Unknown span name.
        let mut j = to_json(&sample_trace());
        let txt = j.to_string().replace("\"select\"", "\"mystery\"");
        j = Json::parse(&txt).unwrap();
        assert!(from_json(&j).err().unwrap().to_string().contains("unknown span name"));
        // Negative duration.
        let txt = to_json(&sample_trace()).to_string().replace("\"dur\":20", "\"dur\":-20");
        assert!(from_json(&Json::parse(&txt).unwrap()).is_err());
        // Metadata missing a required key.
        let txt = to_json(&sample_trace()).to_string().replace("\"workers\"", "\"werkers\"");
        assert!(from_json(&Json::parse(&txt).unwrap()).is_err());
    }
}
