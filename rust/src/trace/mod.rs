//! Span-based step tracing: a low-overhead recorder for per-step,
//! per-worker, per-bucket phase spans, with Chrome trace-event (Perfetto)
//! export and a measured-vs-predicted drift report (`sparkv report`).
//!
//! The netsim predicts where an iteration's wall time goes
//! ([`crate::netsim::IterationBreakdown`]); until this module the trainer
//! measured only coarse per-step aggregates (`StepRecord::wall_s`,
//! `select_us`). The trace subsystem records the *actual* timeline —
//! sample, compute, select/encode, collective rounds, error-feedback
//! apply, barrier wait — from all three runtimes, so the pipelined
//! bucket overlap is visible on a Perfetto track view and the prediction
//! drift needed by ROADMAP item 5's re-tuning loop becomes measurable.
//!
//! ## Span taxonomy
//!
//! | phase        | track        | meaning                                               |
//! |--------------|--------------|-------------------------------------------------------|
//! | `step`       | coordinator  | one whole optimizer step (the umbrella span; its duration is `StepRecord::wall_s`) |
//! | `barrier`    | coordinator  | coordinator wait for the worker phase to complete (`Executor::run_full` / `run_grad`) |
//! | `collective` | coordinator  | one collective engine call (one per bucket; wall at the call site — Σ = `StepRecord::comm_us`) |
//! | `collective` | ring seat    | one rank job on a persistent pool ring thread (`pool:N` only) |
//! | `ef_apply`   | coordinator  | gTop-k globally-dropped restore sweep                  |
//! | `sample`     | worker       | minibatch sampling                                     |
//! | `compute`    | worker       | forward + backward (+ momentum correction)             |
//! | `select`     | worker       | error-feedback accumulate + top-k selection + wire encode |
//! | `ef_apply`   | worker       | residual update `ε ← u − s`                            |
//!
//! Tracks are Chrome trace `tid`s: 0 = coordinator, `1 ..= P` = logical
//! workers (rank + 1), `1000 + r` = pool ring seats. A span is attributed
//! to the **logical worker** it serves regardless of which OS thread ran
//! it — under `threads:N` the bucket producer thread compresses every
//! worker's bucket, and each selection still lands on its worker's
//! track — so span *structure* (phase names and counts per step) is
//! invariant across `serial`/`threads:N`/`pool:N` for a given exchange
//! path. Ring-seat tracks exist only under `pool:N` with ≥ 2 ring ranks
//! (the only runtime with persistent collective threads).
//!
//! ## Overhead discipline
//!
//! Recording is branch-guarded on a plain `bool`: with `trace = off`
//! (the default) every hook is a single predictable branch — no
//! `Instant::now()` calls, no allocation, no atomics on the worker
//! paths — and training is bit-identical to the untraced build (the
//! goldens pin this). With `trace = spans:PATH` each worker stamps into
//! a **preallocated** [`SpanBuf`] ring ([`SpanBuf::CAPACITY`] spans);
//! the buffer travels inside [`crate::coordinator::WorkerState`] through
//! the pool's job/result ping-pong and is drained by the coordinator
//! once per step, so the steady state allocates nothing (overflow
//! increments a `dropped` counter instead of growing).
//! `benches/trace_overhead.rs` pins the end-to-end cost at ≤ 1%.
//!
//! ## Viewing and reporting
//!
//! `sparkv train … --trace spans:trace.json` writes Chrome trace-event
//! JSON: open <https://ui.perfetto.dev> and drag the file in (tracks
//! are the coordinator, one per logical worker, and the pool ring
//! seats; a bucketed `pool:N` run shows bucket *i+1*'s selection
//! overlapping bucket *i*'s collective). `sparkv report trace.json`
//! folds the same file into a measured
//! [`crate::netsim::IterationBreakdown`] and prints the per-phase
//! measured-vs-predicted drift table ([`report::drift_report`]);
//! `--strict` additionally exits non-zero when any phase drifts past
//! its threshold, and malformed traces are hard errors either way.
//! `sparkv tune --calibrate-from trace.json` re-fits the
//! compute/bandwidth calibration scales from the same fold
//! (`Calibrator::fit_from_trace`) instead of running live probes.

mod perfetto;
pub mod report;

pub use perfetto::{load, write};

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Chrome-trace `tid` of the coordinator track.
pub const COORDINATOR_TRACK: u32 = 0;

/// First ring-seat track id (`1000 + rank`); worker tracks are `rank + 1`.
pub const RING_TRACK_BASE: u32 = 1000;

/// The track a logical worker's spans land on (rank + 1; 0 is the
/// coordinator).
pub fn worker_track(rank: usize) -> u32 {
    rank as u32 + 1
}

/// The track a pool ring seat's spans land on.
pub fn ring_track(rank: usize) -> u32 {
    RING_TRACK_BASE + rank as u32
}

/// Span phase — the trace's closed name vocabulary (see the module-level
/// taxonomy table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Step,
    Sample,
    Compute,
    Select,
    Collective,
    EfApply,
    Barrier,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Sample => "sample",
            Phase::Compute => "compute",
            Phase::Select => "select",
            Phase::Collective => "collective",
            Phase::EfApply => "ef_apply",
            Phase::Barrier => "barrier",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Some(match s {
            "step" => Phase::Step,
            "sample" => Phase::Sample,
            "compute" => Phase::Compute,
            "select" => Phase::Select,
            "collective" => Phase::Collective,
            "ef_apply" => Phase::EfApply,
            "barrier" => Phase::Barrier,
            _ => return None,
        })
    }
}

/// One recorded span: a `[t0, t1)` interval (µs since the recorder
/// epoch) on one track, tagged with its step and (for bucketed phases)
/// bucket index (`bucket < 0` ⇒ not bucket-scoped).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub track: u32,
    pub phase: Phase,
    pub step: u32,
    pub bucket: i32,
    pub t0_us: f64,
    pub t1_us: f64,
}

impl Span {
    pub fn dur_us(&self) -> f64 {
        self.t1_us - self.t0_us
    }
}

/// Per-worker span buffer: preallocated at enable time, stamped on the
/// worker's hot path, drained by the coordinator once per step. Lives on
/// [`crate::coordinator::WorkerState`], so under `pool:N` it ships to the
/// pool thread inside the job and comes back with the `PoolResult` — the
/// worker stamps its own spans wherever its state happens to execute.
///
/// Disabled (the default) every method is a branch on a plain bool: no
/// clock reads, no allocation, no shared state.
#[derive(Debug)]
pub struct SpanBuf {
    enabled: bool,
    track: u32,
    step: u32,
    epoch: Option<Instant>,
    spans: Vec<Span>,
    dropped: u64,
}

impl SpanBuf {
    /// Preallocated span capacity per worker per drain interval (one
    /// step): generous for any realistic bucket count; overflow is
    /// counted, never grown.
    pub const CAPACITY: usize = 4096;

    pub fn disabled() -> SpanBuf {
        SpanBuf {
            enabled: false,
            track: 0,
            step: 0,
            epoch: None,
            spans: Vec::new(),
            dropped: 0,
        }
    }

    /// Arm the buffer: one allocation here, none afterwards.
    pub fn enable(&mut self, epoch: Instant, track: u32) {
        self.enabled = true;
        self.track = track;
        self.epoch = Some(epoch);
        self.spans.reserve_exact(Self::CAPACITY.saturating_sub(self.spans.capacity()));
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Tag subsequent stamps with `step` (set by the trainer before the
    /// worker phase launches).
    #[inline]
    pub fn set_step(&mut self, step: u32) {
        self.step = step;
    }

    /// Current time in µs since the epoch — 0.0 (no clock read) when
    /// disabled.
    #[inline]
    pub fn now_us(&self) -> f64 {
        match self.epoch {
            Some(e) if self.enabled => e.elapsed().as_secs_f64() * 1e6,
            _ => 0.0,
        }
    }

    /// Record `[t0_us, now)` as a span of `phase` (no-op when disabled).
    #[inline]
    pub fn stamp(&mut self, phase: Phase, bucket: i32, t0_us: f64) {
        if !self.enabled {
            return;
        }
        let t1_us = self.now_us();
        if self.spans.len() < Self::CAPACITY {
            self.spans.push(Span {
                track: self.track,
                phase,
                step: self.step,
                bucket,
                t0_us,
                t1_us,
            });
        } else {
            self.dropped += 1;
        }
    }

    /// Move everything recorded so far into `out` (the coordinator's
    /// per-step drain); returns the overflow count accumulated since the
    /// last drain.
    pub fn drain_into(&mut self, out: &mut Vec<Span>) -> u64 {
        out.append(&mut self.spans);
        std::mem::take(&mut self.dropped)
    }
}

/// Shared span sink for the pool's persistent ring-seat threads: the
/// seats outlive any one training run, so they stamp through an `Arc`'d
/// sink installed at pool spawn. Disabled it costs one relaxed atomic
/// load per rank job; enabled, two clock reads and one short mutex lock
/// per job (tracing-on only — never on the default path).
///
/// Timestamps are µs since the *sink's* epoch (fixed at pool spawn); the
/// recorder re-bases them onto its own epoch at drain time via
/// [`offset_us`].
#[derive(Debug)]
pub struct SharedSink {
    enabled: AtomicBool,
    step: AtomicU32,
    epoch: Instant,
    inner: Mutex<SinkInner>,
}

#[derive(Debug)]
struct SinkInner {
    spans: Vec<Span>,
    dropped: u64,
}

impl SharedSink {
    /// Per-run span cap (all seats together); overflow is counted.
    pub const CAPACITY: usize = 1 << 16;

    pub fn new() -> SharedSink {
        SharedSink {
            enabled: AtomicBool::new(false),
            step: AtomicU32::new(0),
            epoch: Instant::now(),
            inner: Mutex::new(SinkInner {
                spans: Vec::new(),
                dropped: 0,
            }),
        }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        if on {
            self.inner.lock().unwrap().spans.reserve(Self::CAPACITY);
        }
        self.enabled.store(on, Ordering::Release);
    }

    /// Tag subsequent stamps with `step`. The trainer sets this at step
    /// start; every collective call of the step completes (from the
    /// coordinator's view) before the next step starts, so seat-side
    /// stamps can never race onto the wrong step.
    pub fn set_step(&self, step: u32) {
        self.step.store(step, Ordering::Release);
    }

    /// Current time in µs since the sink epoch.
    #[inline]
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Record `[t0_us, now)` on `track` (callers pre-check
    /// [`SharedSink::is_enabled`]).
    pub fn stamp(&self, track: u32, phase: Phase, t0_us: f64) {
        let t1_us = self.now_us();
        let step = self.step.load(Ordering::Acquire);
        let mut inner = self.inner.lock().unwrap();
        if inner.spans.len() < Self::CAPACITY {
            inner.spans.push(Span {
                track,
                phase,
                step,
                bucket: -1,
                t0_us,
                t1_us,
            });
        } else {
            inner.dropped += 1;
        }
    }

    /// Drain all seat spans, shifting their timestamps by `shift_us`
    /// (the sink-epoch → recorder-epoch offset). Returns the dropped
    /// count.
    pub fn drain_into(&self, shift_us: f64, out: &mut Vec<Span>) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        for mut s in inner.spans.drain(..) {
            s.t0_us += shift_us;
            s.t1_us += shift_us;
            out.push(s);
        }
        std::mem::take(&mut inner.dropped)
    }
}

impl Default for SharedSink {
    fn default() -> SharedSink {
        SharedSink::new()
    }
}

/// Signed microseconds from `from` to `to` (positive when `to` is
/// later). `Instant` subtraction panics on negative spans; this helper
/// handles either ordering.
pub fn offset_us(from: Instant, to: Instant) -> f64 {
    match to.checked_duration_since(from) {
        Some(d) => d.as_secs_f64() * 1e6,
        None => -from.duration_since(to).as_secs_f64() * 1e6,
    }
}

/// What the trainer records, derived from [`crate::config::Trace`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// No tracing at all (the default): every hook is an untaken branch.
    Off,
    /// Per-step aggregates only (`StepRecord::comm_us` timing) — no span
    /// buffers.
    Steps,
    /// Full span recording (implies the per-step aggregates).
    Spans,
}

/// The coordinator-side recorder: owns the trace epoch, the coordinator
/// track, and the accumulated span list the per-worker buffers drain
/// into. Created once per training run.
#[derive(Debug)]
pub struct Recorder {
    mode: TraceMode,
    epoch: Instant,
    spans: Vec<Span>,
    dropped: u64,
}

impl Recorder {
    pub fn new(mode: TraceMode) -> Recorder {
        Recorder {
            mode,
            epoch: Instant::now(),
            spans: Vec::new(),
            dropped: 0,
        }
    }

    /// True when any per-step timing is wanted (`steps` or `spans`) —
    /// gates the `comm_us` clock reads.
    #[inline]
    pub fn is_on(&self) -> bool {
        self.mode != TraceMode::Off
    }

    /// True when full span recording is wanted.
    #[inline]
    pub fn spans_on(&self) -> bool {
        self.mode == TraceMode::Spans
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Current time in µs since the recorder epoch — 0.0 (no clock
    /// read) when tracing is off.
    #[inline]
    pub fn now_us(&self) -> f64 {
        if self.is_on() {
            self.epoch.elapsed().as_secs_f64() * 1e6
        } else {
            0.0
        }
    }

    /// Record a coordinator-track span `[t0_us, now)` (no-op unless span
    /// recording is on).
    #[inline]
    pub fn stamp(&mut self, phase: Phase, step: u32, bucket: i32, t0_us: f64) {
        if !self.spans_on() {
            return;
        }
        let t1_us = self.now_us();
        self.stamp_at(phase, step, bucket, t0_us, t1_us);
    }

    /// Record a coordinator-track span with both endpoints explicit (the
    /// step umbrella span reuses the `wall_s` stamp).
    pub fn stamp_at(&mut self, phase: Phase, step: u32, bucket: i32, t0_us: f64, t1_us: f64) {
        if !self.spans_on() {
            return;
        }
        self.spans.push(Span {
            track: COORDINATOR_TRACK,
            phase,
            step,
            bucket,
            t0_us,
            t1_us,
        });
    }

    /// Drain a worker's span buffer into the trace.
    pub fn absorb(&mut self, buf: &mut SpanBuf) {
        self.dropped += buf.drain_into(&mut self.spans);
    }

    /// Drain the pool ring sink into the trace (re-based onto this
    /// recorder's epoch).
    pub fn absorb_sink(&mut self, sink: &SharedSink) {
        let shift = offset_us(self.epoch, sink.epoch());
        self.dropped += sink.drain_into(shift, &mut self.spans);
    }

    /// Finish the run: package everything recorded with the run
    /// metadata.
    pub fn finish(self, meta: TraceMeta) -> TraceData {
        let mut spans = self.spans;
        // Deterministic order for consumers: by (track, t0, step).
        spans.sort_by(|a, b| {
            a.track
                .cmp(&b.track)
                .then(a.t0_us.total_cmp(&b.t0_us))
                .then(a.step.cmp(&b.step))
        });
        TraceData {
            meta,
            spans,
            dropped: self.dropped,
        }
    }
}

/// Run metadata embedded in the trace file (the `sparkv` top-level
/// object) — everything `sparkv report` needs to rebuild the matching
/// netsim prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    pub workers: usize,
    /// Flat gradient dimension of the traced model.
    pub d: usize,
    pub steps: usize,
    pub k_ratio: f64,
    pub op: String,
    pub parallelism: String,
    /// Bucket count of the traced schedule (1 = monolithic).
    pub buckets: usize,
    pub exchange: String,
    pub wire: String,
    pub select: String,
}

/// A completed trace: metadata + the full span list (sorted by track,
/// then start time).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceData {
    pub meta: TraceMeta,
    pub spans: Vec<Span>,
    /// Spans lost to buffer overflow (0 in any healthy run).
    pub dropped: u64,
}

impl TraceData {
    /// Spans on one track, in start-time order.
    pub fn track(&self, track: u32) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.track == track)
    }

    /// All distinct track ids, ascending.
    pub fn tracks(&self) -> Vec<u32> {
        let mut t: Vec<u32> = self.spans.iter().map(|s| s.track).collect();
        t.sort_unstable();
        t.dedup();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spanbuf_is_inert() {
        let mut b = SpanBuf::disabled();
        assert!(!b.is_enabled());
        assert_eq!(b.now_us(), 0.0);
        b.set_step(7);
        b.stamp(Phase::Compute, -1, 0.0);
        let mut out = Vec::new();
        assert_eq!(b.drain_into(&mut out), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn spanbuf_records_and_caps() {
        let mut b = SpanBuf::disabled();
        b.enable(Instant::now(), worker_track(3));
        b.set_step(2);
        let t0 = b.now_us();
        b.stamp(Phase::Select, 1, t0);
        let mut out = Vec::new();
        assert_eq!(b.drain_into(&mut out), 0);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].track, 4);
        assert_eq!(out[0].step, 2);
        assert_eq!(out[0].bucket, 1);
        assert_eq!(out[0].phase, Phase::Select);
        assert!(out[0].t1_us >= out[0].t0_us);
        // Overflow counts instead of growing.
        for _ in 0..SpanBuf::CAPACITY + 5 {
            b.stamp(Phase::Compute, -1, 0.0);
        }
        let mut out2 = Vec::new();
        assert_eq!(b.drain_into(&mut out2), 5);
        assert_eq!(out2.len(), SpanBuf::CAPACITY);
    }

    #[test]
    fn recorder_off_records_nothing() {
        let mut r = Recorder::new(TraceMode::Off);
        assert!(!r.is_on() && !r.spans_on());
        assert_eq!(r.now_us(), 0.0);
        r.stamp(Phase::Step, 0, -1, 0.0);
        let t = r.finish(test_meta());
        assert!(t.spans.is_empty());
    }

    #[test]
    fn steps_mode_times_but_keeps_no_spans() {
        let mut r = Recorder::new(TraceMode::Steps);
        assert!(r.is_on() && !r.spans_on());
        assert!(r.now_us() >= 0.0);
        r.stamp(Phase::Collective, 0, 0, 0.0);
        assert!(r.finish(test_meta()).spans.is_empty());
    }

    #[test]
    fn recorder_sorts_by_track_then_time() {
        let mut r = Recorder::new(TraceMode::Spans);
        r.stamp_at(Phase::Collective, 0, 0, 5.0, 6.0);
        r.stamp_at(Phase::Barrier, 0, -1, 1.0, 2.0);
        let mut b = SpanBuf::disabled();
        b.enable(r.epoch(), worker_track(0));
        b.stamp(Phase::Compute, -1, 0.0);
        r.absorb(&mut b);
        let t = r.finish(test_meta());
        assert_eq!(t.tracks(), vec![0, 1]);
        let coord: Vec<_> = t.track(0).collect();
        assert_eq!(coord[0].phase, Phase::Barrier);
        assert_eq!(coord[1].phase, Phase::Collective);
    }

    #[test]
    fn shared_sink_rebases_onto_recorder_epoch() {
        let sink = SharedSink::new();
        sink.set_enabled(true);
        sink.set_step(4);
        let t0 = sink.now_us();
        sink.stamp(ring_track(2), Phase::Collective, t0);
        let mut r = Recorder::new(TraceMode::Spans);
        r.absorb_sink(&sink);
        let t = r.finish(test_meta());
        assert_eq!(t.spans.len(), 1);
        let s = t.spans[0];
        assert_eq!(s.track, RING_TRACK_BASE + 2);
        assert_eq!(s.step, 4);
        // The sink epoch predates the recorder's, so the re-based start
        // is negative-or-small but finite, and duration is preserved.
        assert!(s.t0_us.is_finite() && s.t1_us >= s.t0_us);
    }

    #[test]
    fn offset_is_antisymmetric() {
        let a = Instant::now();
        let b = Instant::now();
        assert!((offset_us(a, b) + offset_us(b, a)).abs() < 1.0);
        assert!(offset_us(a, b) >= 0.0);
    }

    #[test]
    fn phase_names_round_trip() {
        for p in [
            Phase::Step,
            Phase::Sample,
            Phase::Compute,
            Phase::Select,
            Phase::Collective,
            Phase::EfApply,
            Phase::Barrier,
        ] {
            assert_eq!(Phase::parse(p.name()), Some(p));
        }
        assert_eq!(Phase::parse("bogus"), None);
    }

    pub(super) fn test_meta() -> TraceMeta {
        TraceMeta {
            workers: 4,
            d: 128,
            steps: 3,
            k_ratio: 0.1,
            op: "topk".into(),
            parallelism: "serial".into(),
            buckets: 1,
            exchange: "dense-ring".into(),
            wire: "raw".into(),
            select: "exact".into(),
        }
    }
}
