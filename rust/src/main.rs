//! `sparkv` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `train`     — run a distributed (simulated-P-worker) training job with
//!   any operator; native or PJRT backend. `--plan plan.json` replays a
//!   tuned plan's compression configuration.
//! * `tune`      — closed-loop search over compression plans (operator ×
//!   k-schedule × buckets × apportionment × runtime) with the netsim cost
//!   model in the loop; writes a deterministic `TunedPlan` JSON.
//! * `report`    — fold a recorded span trace (`train --trace spans:PATH`)
//!   into a measured per-phase breakdown and diff it against the netsim
//!   prediction (drift table; non-zero exit on malformed traces, and on
//!   flagged drift under `--strict`).
//! * `simulate`  — Table 2 cluster simulation (iteration time + scaling
//!   efficiency for every model × operator).
//! * `bench-op`  — operator selection-speed sweep (Fig. 4 shape on CPU).
//! * `analyze`   — Theorem 1 bound sweep (Fig. 5) and π² premise check
//!   (Fig. 3) on Gaussian vectors.
//!
//! See `examples/` for the figure-for-figure reproduction drivers.

use sparkv::analysis::{bound_sweep, pi_curve};
use sparkv::autotune::{
    Calibrator, Candidate, ExhaustiveGrid, GreedyDescent, SearchSpace, SearchStrategy,
    SuccessiveHalving, TuneScenario, TunedPlan, DEFAULT_TUNE_SEED,
};
use sparkv::cluster::scaling_table;
use sparkv::compress::{Compressor, OpKind};
use sparkv::config::{RawConfig, TrainConfig};
use sparkv::coordinator::train;
use sparkv::data::GaussianMixture;
use sparkv::models::NativeMlp;
use sparkv::netsim::{ComputeProfile, Topology};
use sparkv::runtime::PjrtModel;
use sparkv::stats::rng::Pcg64;
use sparkv::util::benchkit::Bench;
use sparkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(true);
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("tune") => cmd_tune(&args),
        Some("report") => cmd_report(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("bench-op") => cmd_bench_op(&args),
        Some("analyze") => cmd_analyze(&args),
        _ => {
            println!(
                "sparkv — Top-K sparsification for distributed deep learning\n\n\
                 USAGE: sparkv <train|tune|report|simulate|bench-op|analyze> [OPTIONS]\n\n\
                 train     --op <dense|topk|randk|dgc|trimmed|gaussiank> --workers N --steps N\n\
                 \x20         [--parallelism serial|threads:N|pool:N] [--buckets none|layers|bytes:N]\n\
                 \x20         [--k-schedule const[:K]|warmup:K0..K,epochs=E|adaptive:DELTA]\n\
                 \x20         [--bucket-apportion size|mass|mass:ema=BETA]\n\
                 \x20         [--global-topk true --exchange dense-ring|tree-sparse]\n\
                 \x20         [--select exact|warm:TAU] [--wire raw|packed|packed+f16]\n\
                 \x20         [--trace off|steps|spans:PATH]\n\
                 \x20         [--steps-per-epoch N] [--config file.toml] [--set train.key=value]\n\
                 \x20         [--plan plan.json] [--backend native|pjrt --model <name>]\n\
                 tune      [--model resnet50] [--nodes 4 --gpus 4] [--k-ratio 0.001]\n\
                 \x20         [--steps-per-epoch 24] [--strategy grid|greedy|halving] [--seed 7]\n\
                 \x20         [--sample N] [--measure] [--measure-steps 8] [--calibrate N]\n\
                 \x20         [--calibrate-from trace.json] [--smoke] [--out results/tuned_plan.json]\n\
                 report    <trace.json> [--strict]\n\
                 simulate  [--k-ratio 0.001] [--nodes 4 --gpus 4]\n\
                 bench-op  [--dims 1000000,4000000,16000000] [--k-ratio 0.001]\n\
                 analyze   [--d 100000] [--ks 100,1000,10000]"
            );
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut raw = match args.get("config") {
        Some(path) => RawConfig::load(path)?,
        None => RawConfig::default(),
    };
    // A tuned plan replays through the ordinary [train] keys (applied
    // after the config file, before explicit CLI keys — flags still win).
    if let Some(path) = args.get("plan") {
        let plan = TunedPlan::load(path)?;
        plan.apply(&mut raw)?;
        println!("plan {path}: {}", plan.summary());
    }
    // CLI conveniences map onto [train] keys.
    for key in [
        "workers",
        "steps",
        "k_ratio",
        "lr",
        "op",
        "batch_size",
        "seed",
        "parallelism",
        "buckets",
        "bucket_apportion",
        "k_schedule",
        "steps_per_epoch",
        "global_topk",
        "exchange",
        "select",
        "wire",
        "trace",
    ] {
        if let Some(v) = args.get(&key.replace('_', "-")).or_else(|| args.get(key)) {
            raw.set(&format!("train.{key}={v}"))?;
        }
    }
    if let Some(setting) = args.get("set") {
        raw.set(setting)?;
    }
    let cfg = TrainConfig::from_raw(&raw)?;
    println!(
        "train: op={} workers={} steps={} k_ratio={} lr={} parallelism={} buckets={} \
         k_schedule={} exchange={} select={} wire={} trace={}",
        cfg.op.name(),
        cfg.workers,
        cfg.steps,
        cfg.k_ratio,
        cfg.lr,
        cfg.parallelism.name(),
        cfg.buckets.name(),
        cfg.k_schedule.name(),
        cfg.exchange.name(),
        cfg.select.name(),
        cfg.wire.name(),
        cfg.trace.name()
    );

    let backend = args.get_or("backend", "native");
    let out = match backend.as_str() {
        "pjrt" => {
            let model_name = args.get_or("model", "mlp");
            let dir = args.get_or("artifacts", "artifacts");
            let mut model = PjrtModel::load(&dir, &model_name)?;
            println!("backend: pjrt ({}), model {model_name} d={}", model.platform(), model.entry.d);
            let batch = model.entry.batch;
            let mut cfg = cfg;
            cfg.batch_size = batch;
            let data = GaussianMixture::new(model.entry.features, model.entry.classes, 2.5, 1.0, cfg.seed);
            train(cfg, &mut model, &data)?
        }
        _ => {
            let features = args.get_parsed_or("features", 64usize);
            let classes = args.get_parsed_or("classes", 10usize);
            let hidden = args.get_parsed_or("hidden", 128usize);
            let mut model = NativeMlp::new(&[features, hidden, hidden, classes]);
            let data = GaussianMixture::new(features, classes, 2.5, 1.0, cfg.seed);
            println!("backend: native mlp d={}", sparkv::models::Model::layout(&model).total());
            train(cfg, &mut model, &data)?
        }
    };

    for (step, loss) in out.metrics.smoothed_loss(out.metrics.steps.len() / 10 + 1) {
        println!("  step {step:>6}  loss {loss:.4}");
    }
    for e in &out.metrics.evals {
        println!("  eval step {:>6}  acc {:.4}  loss {:.4}", e.step, e.accuracy, e.loss);
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, out.metrics.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let smoke = args.flag("smoke");
    let scenario = TuneScenario::from_parts(
        &args.get_or("model", "resnet50"),
        args.get_parsed_or("nodes", 4usize),
        args.get_parsed_or("gpus", 4usize),
        args.get_parsed_or("k-ratio", 0.001f64),
        args.get_parsed_or("steps-per-epoch", if smoke { 3 } else { 24 }),
    )?;
    let space = if smoke {
        SearchSpace::smoke_space()
    } else {
        SearchSpace::default_space()
    };
    let seed: u64 = args.get_parsed_or("seed", DEFAULT_TUNE_SEED);
    anyhow::ensure!(
        seed < (1u64 << 53),
        "--seed must be < 2^53 (the plan records it as a JSON number)"
    );
    // Validate the strategy selection and its flag combinations *before*
    // any measured work, so a bad invocation errors immediately instead
    // of after the calibration probes have trained and printed.
    let strategy_name = args.get_or("strategy", "grid");
    anyhow::ensure!(
        matches!(strategy_name.as_str(), "grid" | "greedy" | "halving"),
        "unknown tune strategy '{strategy_name}': expected grid|greedy|halving"
    );
    // The measured-promotion and subsample knobs only exist on halving —
    // reject rather than silently ignore them elsewhere.
    let halving_only_flags = args.flag("measure")
        || args.get("sample").is_some()
        || args.get("measure-steps").is_some();
    if strategy_name != "halving" && halving_only_flags {
        anyhow::bail!(
            "--measure/--measure-steps/--sample require --strategy halving \
             (got '{strategy_name}')"
        );
    }
    if args.get("measure-steps").is_some() && !args.flag("measure") {
        anyhow::bail!("--measure-steps only applies with --measure");
    }

    // Opt-in measured calibration (--smoke implies a 3-step probe so CI
    // exercises the measured leg on every push). `--calibrate-from`
    // fits from a recorded span trace instead of live probes.
    if args.get("calibrate-from").is_some() && args.get("calibrate").is_some() {
        anyhow::bail!("--calibrate and --calibrate-from are mutually exclusive");
    }
    let calibrate_steps: usize = args.get_parsed_or("calibrate", if smoke { 3 } else { 0 });
    let calibration = if let Some(path) = args.get("calibrate-from") {
        let trace = sparkv::trace::load(path)?;
        let cal = Calibrator::fit_from_trace(&trace, &scenario)?;
        println!(
            "calibration (from {path}, {} traced steps): spawn {:.2} µs/thread, \
             pool dispatch {:.3} µs/thread, compute ×{:.3}, bandwidth ×{:.3}",
            cal.probe_steps,
            cal.spawn_per_thread_s * 1e6,
            cal.pool_dispatch_per_thread_s * 1e6,
            cal.compute_scale,
            cal.bandwidth_scale
        );
        Some(cal)
    } else if calibrate_steps > 0 {
        let cal = Calibrator {
            probe_steps: calibrate_steps,
            ..Calibrator::default()
        }
        .run(&scenario)?;
        println!(
            "calibration ({} probe steps): spawn {:.2} µs/thread, pool dispatch {:.3} µs/thread, \
             compute ×{:.3}, bandwidth ×{:.3}",
            calibrate_steps,
            cal.spawn_per_thread_s * 1e6,
            cal.pool_dispatch_per_thread_s * 1e6,
            cal.compute_scale,
            cal.bandwidth_scale
        );
        Some(cal)
    } else {
        None
    };

    // Measured promotion probe for `halving --measure`: a short real
    // training run per promoted candidate; its mean step wall-clock
    // (StepRecord trace) picks the winner among the survivors.
    let measure_steps: usize = args.get_parsed_or("measure-steps", 8usize);
    let probe = move |c: &Candidate| -> anyhow::Result<f64> {
        let data = GaussianMixture::new(16, 4, 2.5, 1.0, 23);
        let mut model = NativeMlp::new(&[16, 64, 32, 4]);
        let mut cfg = TrainConfig {
            workers: 8,
            steps: measure_steps.max(1),
            eval_every: 0,
            ..TrainConfig::default()
        };
        c.apply(&mut cfg);
        let out = train(cfg, &mut model, &data)?;
        Ok(out.metrics.step_time.mean())
    };

    let mut grid = ExhaustiveGrid;
    let mut greedy = GreedyDescent::default();
    let mut halving = SuccessiveHalving {
        sample: args.get("sample").map(|s| s.parse()).transpose()?,
        measure: if args.flag("measure") {
            Some(Box::new(probe))
        } else {
            None
        },
        ..SuccessiveHalving::default()
    };
    let strategy: &mut dyn SearchStrategy = match strategy_name.as_str() {
        "grid" => &mut grid,
        "greedy" => &mut greedy,
        "halving" => &mut halving,
        _ => unreachable!("strategy name validated before the calibration probes"),
    };

    println!(
        "tune — {} on {} GPUs ({}×{}), k = {}·d, {} virtual steps/epoch, space of {} candidates",
        scenario.model.name,
        scenario.workers(),
        scenario.topo.nodes,
        scenario.topo.gpus_per_node,
        scenario.k_ratio,
        scenario.steps_per_epoch,
        space.len()
    );
    let plan = sparkv::autotune::tune(&scenario, &space, strategy, seed, calibration.as_ref());
    println!(
        "\nleaderboard (predicted s/epoch; halving keeps eliminated rows at reduced fidelity):"
    );
    for (i, e) in plan.leaderboard.iter().enumerate() {
        let mut note = String::new();
        if let Some(m) = e.measured_step_s {
            note.push_str(&format!("  [measured {:.1} µs/step]", m * 1e6));
        }
        if e.steps != scenario.steps_per_epoch {
            note.push_str(&format!("  (over {} of {} steps)", e.steps, scenario.steps_per_epoch));
        }
        println!("  {:>2}. {:<60} {:>10.4}{note}", i + 1, e.name, e.epoch_s);
    }
    println!("\n{}", plan.summary());

    let out_path = args.get_or("out", "results/tuned_plan.json");
    plan.save(&out_path)?;
    println!("wrote {out_path} (replay with: sparkv train --plan {out_path})");
    Ok(())
}

/// `sparkv report <trace.json>` — fold a recorded span trace into the
/// measured per-phase breakdown and diff it against the netsim
/// prediction rebuilt from the trace's own metadata. Malformed traces
/// are hard errors (non-zero exit); `--strict` additionally fails the
/// run when any drift row is flagged.
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("trace")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("usage: sparkv report <trace.json> [--strict]"))?;
    let trace = sparkv::trace::load(&path)?;
    println!(
        "report — {path}: op={} workers={} d={} steps={} k_ratio={} parallelism={} \
         buckets={} exchange={} wire={} select={}",
        trace.meta.op,
        trace.meta.workers,
        trace.meta.d,
        trace.meta.steps,
        trace.meta.k_ratio,
        trace.meta.parallelism,
        trace.meta.buckets,
        trace.meta.exchange,
        trace.meta.wire,
        trace.meta.select
    );
    let report = sparkv::trace::report::drift_report(&trace)?;
    print!("{}", report.render());
    if args.flag("strict") && !report.ok() {
        anyhow::bail!("--strict: drift above threshold (see flagged rows)");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let k_ratio = args.get_parsed_or("k-ratio", 0.001f64);
    let nodes = args.get_parsed_or("nodes", 4usize);
    let gpus = args.get_parsed_or("gpus", 4usize);
    let topo = Topology::new(
        nodes,
        gpus,
        sparkv::netsim::LinkSpec::pcie3_x16(),
        sparkv::netsim::LinkSpec::ethernet_10g(),
    );
    let ops = [
        OpKind::Dense,
        OpKind::TopK,
        OpKind::Dgc,
        OpKind::Trimmed,
        OpKind::GaussianK,
    ];
    let table = scaling_table(&ComputeProfile::paper_models(), &ops, &topo, k_ratio);
    println!(
        "Table 2 reproduction — {} GPUs ({} nodes × {}), k = {k_ratio}·d\n",
        topo.world_size(),
        nodes,
        gpus
    );
    println!("{}", table.render());
    if let Some(path) = args.get("out") {
        std::fs::write(path, table.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_bench_op(args: &Args) -> anyhow::Result<()> {
    let dims = args.get_list("dims", &["1000000", "4000000", "16000000"]);
    let k_ratio = args.get_parsed_or("k-ratio", 0.001f64);
    let mut bench = Bench::from_env(0.5);
    for dim_s in &dims {
        let d: usize = dim_s.parse().map_err(|_| anyhow::anyhow!("bad dim {dim_s}"))?;
        let k = ((d as f64 * k_ratio) as usize).max(1);
        let mut rng = Pcg64::seed(7);
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        for op in [OpKind::TopK, OpKind::Dgc, OpKind::GaussianK] {
            let mut c = op.build(3);
            let mut ws = sparkv::compress::Workspace::new();
            bench.run(&format!("{}/d={d}", op.name()), || {
                let s = c.compress_step(&u, k, &mut ws);
                ws.recycle(std::hint::black_box(s));
            });
        }
    }
    println!("{}", bench.report());
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let d = args.get_parsed_or("d", 100_000usize);
    let ks = args.get_list("ks", &["100", "1000", "5000", "10000", "25000", "50000"]);
    let mut rng = Pcg64::seed(1);
    let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let ks: Vec<usize> = ks.iter().map(|s| s.parse().unwrap_or(0)).collect();
    println!("Theorem 1 bound sweep on N(0,1) vector, d = {d}:");
    println!(
        "{:>8} {:>12} {:>12} {:>12}",
        "k", "exact", "(1-k/d)^2", "1-k/d"
    );
    for p in bound_sweep(&u, &ks) {
        println!(
            "{:>8} {:>12.6} {:>12.6} {:>12.6}",
            p.k, p.exact, p.ours, p.classical
        );
    }
    let pi2 = pi_curve::pi_squared(&u);
    let check = pi_curve::PiCurveCheck::evaluate(&pi2, (d / 1000).max(1));
    println!(
        "\nπ² premise (Fig. 3): convexity violations {:.2}%, above-line {:.2}%, premise {}",
        check.convexity_violation_frac * 100.0,
        check.above_line_frac * 100.0,
        if check.premise_holds() { "HOLDS" } else { "FAILS" }
    );

    // Sanity: GaussianK on this vector lands near k.
    let k = ks.first().copied().unwrap_or(d / 1000).max(1);
    let mut gk = sparkv::compress::GaussianK::new();
    let s = gk.compress_step(&u, k, &mut sparkv::compress::Workspace::new());
    println!("Gaussian_k(k={k}) selected {} elements", s.nnz());
    Ok(())
}
