//! `sparkv` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `train`     — run a distributed (simulated-P-worker) training job with
//!   any operator; native or PJRT backend.
//! * `simulate`  — Table 2 cluster simulation (iteration time + scaling
//!   efficiency for every model × operator).
//! * `bench-op`  — operator selection-speed sweep (Fig. 4 shape on CPU).
//! * `analyze`   — Theorem 1 bound sweep (Fig. 5) and π² premise check
//!   (Fig. 3) on Gaussian vectors.
//!
//! See `examples/` for the figure-for-figure reproduction drivers.

use sparkv::analysis::{bound_sweep, pi_curve};
use sparkv::cluster::scaling_table;
use sparkv::compress::{Compressor, OpKind};
use sparkv::config::{RawConfig, TrainConfig};
use sparkv::coordinator::train;
use sparkv::data::GaussianMixture;
use sparkv::models::NativeMlp;
use sparkv::netsim::{ComputeProfile, Topology};
use sparkv::runtime::PjrtModel;
use sparkv::stats::rng::Pcg64;
use sparkv::util::benchkit::Bench;
use sparkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(true);
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("bench-op") => cmd_bench_op(&args),
        Some("analyze") => cmd_analyze(&args),
        _ => {
            println!(
                "sparkv — Top-K sparsification for distributed deep learning\n\n\
                 USAGE: sparkv <train|simulate|bench-op|analyze> [OPTIONS]\n\n\
                 train     --op <dense|topk|randk|dgc|trimmed|gaussiank> --workers N --steps N\n\
                 \x20         [--parallelism serial|threads:N|pool:N] [--buckets none|layers|bytes:N]\n\
                 \x20         [--k-schedule const[:K]|warmup:K0..K,epochs=E|adaptive:DELTA]\n\
                 \x20         [--bucket-apportion size|mass]\n\
                 \x20         [--steps-per-epoch N] [--config file.toml] [--set train.key=value]\n\
                 \x20         [--backend native|pjrt --model <name>]\n\
                 simulate  [--k-ratio 0.001] [--nodes 4 --gpus 4]\n\
                 bench-op  [--dims 1000000,4000000,16000000] [--k-ratio 0.001]\n\
                 analyze   [--d 100000] [--ks 100,1000,10000]"
            );
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut raw = match args.get("config") {
        Some(path) => RawConfig::load(path)?,
        None => RawConfig::default(),
    };
    // CLI conveniences map onto [train] keys.
    for key in [
        "workers",
        "steps",
        "k_ratio",
        "lr",
        "op",
        "batch_size",
        "seed",
        "parallelism",
        "buckets",
        "bucket_apportion",
        "k_schedule",
        "steps_per_epoch",
    ] {
        if let Some(v) = args.get(&key.replace('_', "-")).or_else(|| args.get(key)) {
            raw.set(&format!("train.{key}={v}"))?;
        }
    }
    if let Some(setting) = args.get("set") {
        raw.set(setting)?;
    }
    let cfg = TrainConfig::from_raw(&raw)?;
    println!(
        "train: op={} workers={} steps={} k_ratio={} lr={} parallelism={} buckets={} k_schedule={}",
        cfg.op.name(),
        cfg.workers,
        cfg.steps,
        cfg.k_ratio,
        cfg.lr,
        cfg.parallelism.name(),
        cfg.buckets.name(),
        cfg.k_schedule.name()
    );

    let backend = args.get_or("backend", "native");
    let out = match backend.as_str() {
        "pjrt" => {
            let model_name = args.get_or("model", "mlp");
            let dir = args.get_or("artifacts", "artifacts");
            let mut model = PjrtModel::load(&dir, &model_name)?;
            println!("backend: pjrt ({}), model {model_name} d={}", model.platform(), model.entry.d);
            let batch = model.entry.batch;
            let mut cfg = cfg;
            cfg.batch_size = batch;
            let data = GaussianMixture::new(model.entry.features, model.entry.classes, 2.5, 1.0, cfg.seed);
            train(cfg, &mut model, &data)?
        }
        _ => {
            let features = args.get_parsed_or("features", 64usize);
            let classes = args.get_parsed_or("classes", 10usize);
            let hidden = args.get_parsed_or("hidden", 128usize);
            let mut model = NativeMlp::new(&[features, hidden, hidden, classes]);
            let data = GaussianMixture::new(features, classes, 2.5, 1.0, cfg.seed);
            println!("backend: native mlp d={}", sparkv::models::Model::layout(&model).total());
            train(cfg, &mut model, &data)?
        }
    };

    for (step, loss) in out.metrics.smoothed_loss(out.metrics.steps.len() / 10 + 1) {
        println!("  step {step:>6}  loss {loss:.4}");
    }
    for e in &out.metrics.evals {
        println!("  eval step {:>6}  acc {:.4}  loss {:.4}", e.step, e.accuracy, e.loss);
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, out.metrics.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let k_ratio = args.get_parsed_or("k-ratio", 0.001f64);
    let nodes = args.get_parsed_or("nodes", 4usize);
    let gpus = args.get_parsed_or("gpus", 4usize);
    let topo = Topology::new(
        nodes,
        gpus,
        sparkv::netsim::LinkSpec::pcie3_x16(),
        sparkv::netsim::LinkSpec::ethernet_10g(),
    );
    let ops = [
        OpKind::Dense,
        OpKind::TopK,
        OpKind::Dgc,
        OpKind::Trimmed,
        OpKind::GaussianK,
    ];
    let table = scaling_table(&ComputeProfile::paper_models(), &ops, &topo, k_ratio);
    println!(
        "Table 2 reproduction — {} GPUs ({} nodes × {}), k = {k_ratio}·d\n",
        topo.world_size(),
        nodes,
        gpus
    );
    println!("{}", table.render());
    if let Some(path) = args.get("out") {
        std::fs::write(path, table.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_bench_op(args: &Args) -> anyhow::Result<()> {
    let dims = args.get_list("dims", &["1000000", "4000000", "16000000"]);
    let k_ratio = args.get_parsed_or("k-ratio", 0.001f64);
    let mut bench = Bench::from_env(0.5);
    for dim_s in &dims {
        let d: usize = dim_s.parse().map_err(|_| anyhow::anyhow!("bad dim {dim_s}"))?;
        let k = ((d as f64 * k_ratio) as usize).max(1);
        let mut rng = Pcg64::seed(7);
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        for op in [OpKind::TopK, OpKind::Dgc, OpKind::GaussianK] {
            let mut c = op.build(3);
            let mut ws = sparkv::compress::Workspace::new();
            bench.run(&format!("{}/d={d}", op.name()), || {
                let s = c.compress_step(&u, k, &mut ws);
                ws.recycle(std::hint::black_box(s));
            });
        }
    }
    println!("{}", bench.report());
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let d = args.get_parsed_or("d", 100_000usize);
    let ks = args.get_list("ks", &["100", "1000", "5000", "10000", "25000", "50000"]);
    let mut rng = Pcg64::seed(1);
    let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
    let ks: Vec<usize> = ks.iter().map(|s| s.parse().unwrap_or(0)).collect();
    println!("Theorem 1 bound sweep on N(0,1) vector, d = {d}:");
    println!(
        "{:>8} {:>12} {:>12} {:>12}",
        "k", "exact", "(1-k/d)^2", "1-k/d"
    );
    for p in bound_sweep(&u, &ks) {
        println!(
            "{:>8} {:>12.6} {:>12.6} {:>12.6}",
            p.k, p.exact, p.ours, p.classical
        );
    }
    let pi2 = pi_curve::pi_squared(&u);
    let check = pi_curve::PiCurveCheck::evaluate(&pi2, (d / 1000).max(1));
    println!(
        "\nπ² premise (Fig. 3): convexity violations {:.2}%, above-line {:.2}%, premise {}",
        check.convexity_violation_frac * 100.0,
        check.above_line_frac * 100.0,
        if check.premise_holds() { "HOLDS" } else { "FAILS" }
    );

    // Sanity: GaussianK on this vector lands near k.
    let k = ks.first().copied().unwrap_or(d / 1000).max(1);
    let mut gk = sparkv::compress::GaussianK::new();
    let s = gk.compress_step(&u, k, &mut sparkv::compress::Workspace::new());
    println!("Gaussian_k(k={k}) selected {} elements", s.nnz());
    Ok(())
}
