//! Per-step k-scheduling: the compression *plan* engine.
//!
//! The paper's density sweeps (§4, Table 2) fix k for a whole run, but
//! follow-up work varies it over training: Adaptive Top-K (Ruan et al.
//! 2022) picks k per step from gradient statistics, and density
//! *schedules* dominate end-to-end scaling efficiency in the
//! supercomputing study of Yoon & Oh (2022). This module turns the static
//! `(operator, k)` pair into a per-step [`StepPlan`] resolved by a
//! [`KPolicy`]:
//!
//! * [`Constant`] — today's behaviour: `k = round(d · k_ratio)` every
//!   step (the `const` schedule; bit-identical to the pre-schedule path).
//! * [`WarmupDecay`] — exponential *density* decay from `R0` to `R` over
//!   the first `E` epochs (`warmup:R0..R,epochs=E`), then constant at
//!   `R`. Start dense while gradients are chaotic, sparsify as training
//!   settles.
//! * [`AdaptiveMass`] — pick the smallest k whose top-|u| coordinates
//!   capture a target fraction δ of ‖u‖² (`adaptive:DELTA`), estimated
//!   from the rank-order fold of *every* worker's |u| [`Histogram`]
//!   ([`fold_feedback_histograms`]); the estimate from step t steers k at
//!   step t + 1 (open loop at step 0).
//!
//! ## The `k_schedule` grammar (TOML `[train]` key and `--set` override)
//!
//! ```text
//! k_schedule = "const"                      # follow k_ratio (default)
//! k_schedule = "const:K"                    # fixed density K
//! k_schedule = "warmup:K0..K,epochs=E"      # exponential decay K0 → K
//! k_schedule = "adaptive:DELTA"             # smallest k with δ of ‖u‖²
//! ```
//!
//! `K`, `K0`, `DELTA` are densities/fractions in (0, 1] with `K0 ≥ K`
//! (warmup *decays* — a reversed range is rejected at parse/validate
//! time); `E` is a number of epochs, converted to steps via the
//! `steps_per_epoch` config key (synthetic data streams have no natural
//! epoch boundary, so the epoch length is explicit configuration).
//!
//! ## Contracts
//!
//! * Every resolved plan satisfies `1 ≤ k_t ≤ d` ([`Scheduler::plan`]
//!   clamps; property-locked in `tests/schedule_equivalence.rs`).
//! * `const` schedules resolve the *identical* k the pre-schedule trainer
//!   computed (`round(d · k_ratio)` clamped to `[1, d]`), so constant
//!   runs are bit-for-bit reproductions of the old path.
//! * Policies are `Send`: the trainer owns the scheduler on the
//!   coordinator thread; workers only see the resolved `k_t`.
//! * Feedback ([`Scheduler::observe`]) is collected from **every**
//!   worker, folded in rank order ([`fold_feedback_histograms`]), and
//!   applied after the step's fold, so serial, threaded, and pooled runs
//!   resolve identical k sequences. (Earlier revisions sampled worker 0
//!   only — a skewed rank-0 shard then dictated the whole cluster's k;
//!   `folded_feedback_is_not_dominated_by_worker0` pins the fix.)

use crate::stats::histogram::Histogram;

/// Bins used for the |u| feedback histogram ([`feedback_histogram`]).
/// Coarse is fine: the adaptive policy only needs the energy-vs-count
/// trade-off curve, not the exact distribution.
pub const FEEDBACK_BINS: usize = 128;

/// A parsed `k_schedule` specification (see the module docs for the
/// grammar). Lives in the config layer; [`Scheduler::for_run`] resolves
/// it into a policy once the model dimension d is known.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KSchedule {
    /// `const` (follow the `k_ratio` key — the default) or `const:K`
    /// (fixed density K, overriding `k_ratio`).
    Const(Option<f64>),
    /// `warmup:K0..K,epochs=E` — exponential density decay K0 → K over
    /// the first E epochs, then constant at K.
    Warmup { from: f64, to: f64, epochs: usize },
    /// `adaptive:DELTA` — smallest k capturing DELTA of ‖u‖².
    Adaptive { delta: f64 },
}

impl Default for KSchedule {
    fn default() -> Self {
        KSchedule::Const(None)
    }
}

impl KSchedule {
    /// Parse a config/CLI value (see the module-docs grammar). The value
    /// invariants live in [`KSchedule::validate`], which runs on every
    /// parse — grammar shape and value constraints cannot drift apart.
    pub fn parse(s: &str) -> anyhow::Result<KSchedule> {
        let t = s.trim().to_ascii_lowercase();
        let grammar = "const[:K] | warmup:K0..K,epochs=E | adaptive:DELTA";
        let bad = || anyhow::anyhow!("bad k_schedule '{s}': expected {grammar}");
        let spec = if t == "const" {
            KSchedule::Const(None)
        } else if let Some(rest) = t.strip_prefix("const:") {
            KSchedule::Const(Some(rest.parse().map_err(|_| bad())?))
        } else if let Some(rest) = t.strip_prefix("warmup:") {
            let (range, epochs) = rest.split_once(',').ok_or_else(bad)?;
            let (from, to) = range.split_once("..").ok_or_else(bad)?;
            KSchedule::Warmup {
                from: from.parse().map_err(|_| bad())?,
                to: to.parse().map_err(|_| bad())?,
                epochs: epochs
                    .strip_prefix("epochs=")
                    .ok_or_else(bad)?
                    .parse()
                    .map_err(|_| bad())?,
            }
        } else if let Some(rest) = t.strip_prefix("adaptive:") {
            KSchedule::Adaptive {
                delta: rest.parse().map_err(|_| bad())?,
            }
        } else {
            return Err(bad());
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Display form (round-trips through [`KSchedule::parse`]).
    pub fn name(&self) -> String {
        match self {
            KSchedule::Const(None) => "const".to_string(),
            KSchedule::Const(Some(r)) => format!("const:{r}"),
            KSchedule::Warmup { from, to, epochs } => {
                format!("warmup:{from}..{to},epochs={epochs}")
            }
            KSchedule::Adaptive { delta } => format!("adaptive:{delta}"),
        }
    }

    /// Validate the spec's invariants (config-level check).
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            KSchedule::Const(None) => Ok(()),
            KSchedule::Const(Some(r)) => {
                anyhow::ensure!(r > 0.0 && r <= 1.0, "k_schedule const:K needs K in (0, 1]");
                Ok(())
            }
            KSchedule::Warmup { from, to, epochs } => {
                anyhow::ensure!(
                    from > 0.0 && from <= 1.0 && to > 0.0 && to <= 1.0,
                    "k_schedule warmup densities must be in (0, 1]"
                );
                anyhow::ensure!(
                    from >= to,
                    "k_schedule warmup decays: K0 must be >= K (got {from}..{to})"
                );
                anyhow::ensure!(epochs >= 1, "k_schedule warmup needs epochs >= 1");
                Ok(())
            }
            KSchedule::Adaptive { delta } => {
                anyhow::ensure!(
                    delta > 0.0 && delta <= 1.0,
                    "k_schedule adaptive:DELTA needs DELTA in (0, 1]"
                );
                Ok(())
            }
        }
    }
}

/// The resolved compression plan for one step: `k` is already clamped to
/// `[1, d]`; `density = k / d`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepPlan {
    pub k: usize,
    pub density: f64,
}

/// A per-step k policy. Implementations must be deterministic functions
/// of `(step, observed history)` — the trainer relies on that for its
/// serial/threaded bit-identity guarantee.
pub trait KPolicy: Send {
    /// The k this policy wants for `step`. The [`Scheduler`] clamps the
    /// result to `[1, d]`; implementations should stay in range anyway.
    fn k_for_step(&mut self, step: usize) -> usize;

    /// Feed back the cluster-wide |u| histogram after `step` — the
    /// rank-order fold of every worker's [`feedback_histogram`]
    /// ([`fold_feedback_histograms`]); adaptive policies steer k at
    /// step + 1 with it. Default: ignored.
    fn observe(&mut self, _step: usize, _u_abs_hist: &Histogram) {}

    /// Whether this policy consumes [`KPolicy::observe`] feedback (lets
    /// the trainer skip building the histogram when nobody listens).
    fn wants_feedback(&self) -> bool {
        false
    }

    /// Human-readable name for metrics/reports.
    fn name(&self) -> String;
}

/// Fixed k every step — `round(d · ratio)` clamped to `[1, d]`, the exact
/// expression the pre-schedule trainer used.
pub struct Constant {
    k: usize,
    ratio: f64,
}

impl Constant {
    pub fn new(d: usize, ratio: f64) -> Constant {
        let k = ((d as f64 * ratio).round() as usize).clamp(1, d.max(1));
        Constant { k, ratio }
    }
}

impl KPolicy for Constant {
    fn k_for_step(&mut self, _step: usize) -> usize {
        self.k
    }

    fn name(&self) -> String {
        format!("const:{}", self.ratio)
    }
}

/// Exponential density decay `from → to` over `warmup_steps` steps, then
/// constant at `to`. With `from > to` the density trace is non-increasing
/// (strictly decreasing wherever the rounded k still moves).
pub struct WarmupDecay {
    d: usize,
    from: f64,
    to: f64,
    warmup_steps: usize,
}

impl WarmupDecay {
    pub fn new(d: usize, from: f64, to: f64, warmup_steps: usize) -> WarmupDecay {
        WarmupDecay {
            d,
            from,
            to,
            warmup_steps: warmup_steps.max(1),
        }
    }

    /// The (un-rounded) density at `step`.
    pub fn density_at(&self, step: usize) -> f64 {
        warmup_density(self.from, self.to, self.warmup_steps, step)
    }
}

/// The warmup-decay density curve, shared with the open-loop trace used
/// by the netsim scheduled sweeps ([`density_trace`]).
fn warmup_density(from: f64, to: f64, warmup_steps: usize, step: usize) -> f64 {
    let w = warmup_steps.max(1);
    if step >= w {
        return to;
    }
    from * (to / from).powf(step as f64 / w as f64)
}

impl KPolicy for WarmupDecay {
    fn k_for_step(&mut self, step: usize) -> usize {
        let rho = self.density_at(step);
        ((self.d as f64 * rho).round() as usize).clamp(1, self.d.max(1))
    }

    fn name(&self) -> String {
        format!(
            "warmup:{}..{},steps={}",
            self.from, self.to, self.warmup_steps
        )
    }
}

/// Smallest k whose top-|u| coordinates capture `delta` of ‖u‖²,
/// estimated from the previous step's |u| histogram (folded across all
/// workers — [`fold_feedback_histograms`]). The
/// energy in bin i is approximated as `count_i · center_i²`; walking bins
/// from the largest magnitude down until the accumulated energy reaches
/// `delta · Σ energy` yields the count — an O(bins) estimate whose
/// granularity is the bin width. Starts open-loop at `round(d · k_ratio)`.
pub struct AdaptiveMass {
    d: usize,
    delta: f64,
    k: usize,
}

impl AdaptiveMass {
    pub fn new(d: usize, delta: f64, init_ratio: f64) -> AdaptiveMass {
        AdaptiveMass {
            d,
            delta,
            k: ((d as f64 * init_ratio).round() as usize).clamp(1, d.max(1)),
        }
    }
}

impl KPolicy for AdaptiveMass {
    fn k_for_step(&mut self, _step: usize) -> usize {
        self.k
    }

    fn observe(&mut self, _step: usize, hist: &Histogram) {
        if hist.hi <= 1e-12 || hist.total == 0 {
            // Degenerate |u| ≈ 0 histogram (feedback_histogram floors the
            // span at 1e-12): no usable energy profile — keep the current
            // k rather than collapsing the walk into the zero bin.
            return;
        }
        let centers = hist.centers();
        let mut total = 0.0f64;
        for (&c, &x) in hist.counts.iter().zip(&centers) {
            total += c as f64 * x * x;
        }
        if total <= 0.0 {
            return;
        }
        let target = self.delta * total;
        let mut acc = 0.0f64;
        let mut count = 0u64;
        for i in (0..hist.counts.len()).rev() {
            acc += hist.counts[i] as f64 * centers[i] * centers[i];
            count += hist.counts[i];
            if acc >= target {
                break;
            }
        }
        self.k = (count as usize).clamp(1, self.d.max(1));
    }

    fn wants_feedback(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("adaptive:{}", self.delta)
    }
}

/// The trainer-facing engine: owns the policy, clamps its output, and
/// exposes the feedback hook.
pub struct Scheduler {
    policy: Box<dyn KPolicy>,
    d: usize,
}

impl Scheduler {
    pub fn new(policy: Box<dyn KPolicy>, d: usize) -> Scheduler {
        Scheduler { policy, d }
    }

    /// Resolve a spec into a running scheduler for a d-dimensional model.
    /// `k_ratio` is the base density (`const` default and the adaptive
    /// policy's open-loop start); `steps_per_epoch` converts the warmup
    /// grammar's `epochs=E` into steps.
    pub fn for_run(
        spec: &KSchedule,
        k_ratio: f64,
        steps_per_epoch: usize,
        d: usize,
    ) -> Scheduler {
        let policy: Box<dyn KPolicy> = match *spec {
            KSchedule::Const(r) => Box::new(Constant::new(d, r.unwrap_or(k_ratio))),
            KSchedule::Warmup { from, to, epochs } => Box::new(WarmupDecay::new(
                d,
                from,
                to,
                epochs.saturating_mul(steps_per_epoch.max(1)),
            )),
            KSchedule::Adaptive { delta } => Box::new(AdaptiveMass::new(d, delta, k_ratio)),
        };
        Scheduler::new(policy, d)
    }

    /// The plan for `step`, with `1 ≤ k ≤ d` enforced.
    pub fn plan(&mut self, step: usize) -> StepPlan {
        let d = self.d.max(1);
        let k = self.policy.k_for_step(step).clamp(1, d);
        StepPlan {
            k,
            density: k as f64 / d as f64,
        }
    }

    /// Feed the step's |u| histogram — folded across all workers
    /// ([`fold_feedback_histograms`]) — back to the policy.
    pub fn observe(&mut self, step: usize, u_abs_hist: &Histogram) {
        self.policy.observe(step, u_abs_hist);
    }

    pub fn wants_feedback(&self) -> bool {
        self.policy.wants_feedback()
    }

    pub fn name(&self) -> String {
        self.policy.name()
    }
}

/// Build the |u| feedback histogram the adaptive policies consume
/// (`FEEDBACK_BINS` uniform bins over `[0, max |u|]`).
pub fn feedback_histogram(u: &[f32]) -> Histogram {
    let mut span = 0.0f64;
    for &v in u {
        span = span.max((v as f64).abs());
    }
    let mut h = Histogram::new(0.0, span.max(1e-12), FEEDBACK_BINS);
    for &v in u {
        h.push((v as f64).abs());
    }
    h
}

/// Fold the per-worker |u| feedback histograms (rank order) into one
/// cluster-wide histogram over the common span `max_w hi_w`: each source
/// bin's count lands in the destination bin containing its center — an
/// O(W · bins) re-bin whose granularity loss is at most one bin width.
/// With a single input this is the identity (bin centers re-bin onto
/// themselves), so one-worker runs keep their exact pre-fold feedback;
/// the walk order is deterministic, so every runtime folds identically.
pub fn fold_feedback_histograms(hists: &[Histogram]) -> Histogram {
    assert!(!hists.is_empty(), "feedback fold needs at least one worker histogram");
    let span = hists.iter().fold(1e-12f64, |m, h| m.max(h.hi));
    let mut out = Histogram::new(0.0, span, FEEDBACK_BINS);
    for h in hists {
        let centers = h.centers();
        for (&c, &x) in h.counts.iter().zip(&centers) {
            if c == 0 {
                continue;
            }
            let b = ((x / span * FEEDBACK_BINS as f64).floor().max(0.0) as usize)
                .min(FEEDBACK_BINS - 1);
            out.counts[b] += c;
            out.total += c;
        }
    }
    out
}

/// The open-loop per-step *density* trace of a schedule, independent of
/// any concrete model dimension — the input of the netsim scheduled
/// sweeps ([`crate::cluster::scaling_table_scheduled`]), which quantize
/// it per model via `round(d · ρ_t)`. `Adaptive` has no open-loop trace
/// (it needs gradient feedback the cost model cannot provide) and is
/// reported at its initial density.
pub fn density_trace(
    spec: &KSchedule,
    k_ratio: f64,
    steps_per_epoch: usize,
    steps: usize,
) -> Vec<f64> {
    (0..steps)
        .map(|t| match *spec {
            KSchedule::Const(r) => r.unwrap_or(k_ratio),
            KSchedule::Warmup { from, to, epochs } => {
                warmup_density(from, to, epochs.saturating_mul(steps_per_epoch.max(1)), t)
            }
            KSchedule::Adaptive { .. } => k_ratio,
        })
        .map(|rho| rho.clamp(f64::MIN_POSITIVE, 1.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn grammar_roundtrip_and_errors() {
        for s in [
            "const",
            "const:0.01",
            "warmup:0.05..0.001,epochs=3",
            "adaptive:0.95",
        ] {
            let spec = KSchedule::parse(s).unwrap();
            assert_eq!(KSchedule::parse(&spec.name()).unwrap(), spec, "{s}");
            spec.validate().unwrap();
        }
        assert_eq!(KSchedule::parse("CONST").unwrap(), KSchedule::Const(None));
        for bad in [
            "",
            "linear:0.1",
            "const:0",
            "const:2.0",
            "warmup:0.05,epochs=3",
            "warmup:0.05..0.001",
            "warmup:0.05..0.001,epochs=0",
            "warmup:0.05..1.5,epochs=2",
            "warmup:0.001..0.05,epochs=2", // reversed range: warmup decays
            "adaptive:0",
            "adaptive:1.5",
            "adaptive:x",
        ] {
            assert!(KSchedule::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn constant_matches_trainer_expression() {
        // The exact pre-schedule trainer expression, for a sweep of (d, ratio).
        for &(d, ratio) in &[(3300usize, 0.001f64), (10, 0.5), (7, 1.0), (1, 0.001)] {
            let mut c = Constant::new(d, ratio);
            let want = ((d as f64 * ratio).round() as usize).clamp(1, d);
            assert_eq!(c.k_for_step(0), want, "d={d} ratio={ratio}");
            assert_eq!(c.k_for_step(999), want);
        }
    }

    #[test]
    fn warmup_decays_to_target() {
        let d = 100_000;
        let mut w = WarmupDecay::new(d, 0.05, 0.001, 10);
        let ks: Vec<usize> = (0..15).map(|t| w.k_for_step(t)).collect();
        assert_eq!(ks[0], 5000); // round(d · 0.05)
        for t in 1..15 {
            assert!(ks[t] <= ks[t - 1], "k not non-increasing at {t}: {ks:?}");
        }
        // Strictly decreasing while the density still moves the rounded k.
        assert!(ks[1] < ks[0] && ks[5] < ks[4]);
        assert_eq!(ks[10], 100); // round(d · 0.001) after warmup
        assert_eq!(ks[14], 100);
    }

    #[test]
    fn adaptive_tracks_energy_mass() {
        // Spiky u: 10 coordinates carry essentially all the energy, so the
        // adaptive k must collapse toward ~10. Gaussian u spreads energy,
        // so the same δ needs a much larger k.
        let d = 20_000;
        let mut spiky = vec![1e-4f32; d];
        for i in 0..10 {
            spiky[i * 7] = 100.0;
        }
        let mut p = AdaptiveMass::new(d, 0.9, 0.001);
        p.observe(0, &feedback_histogram(&spiky));
        let k_spiky = p.k_for_step(1);
        assert!(k_spiky <= 200, "spiky k {k_spiky} should be tiny");

        let mut rng = Pcg64::seed(5);
        let gauss: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let mut p2 = AdaptiveMass::new(d, 0.9, 0.001);
        p2.observe(0, &feedback_histogram(&gauss));
        let k_gauss = p2.k_for_step(1);
        assert!(
            k_gauss > 10 * k_spiky.max(1),
            "gaussian k {k_gauss} vs spiky k {k_spiky}"
        );
        // All-zero feedback keeps the previous k.
        let before = p2.k_for_step(2);
        p2.observe(2, &feedback_histogram(&vec![0.0f32; d]));
        assert_eq!(p2.k_for_step(3), before);
    }

    /// Tentpole invariant: every policy yields 1 ≤ k_t ≤ d for random
    /// dimensions, specs, and (for adaptive) random feedback.
    #[test]
    fn prop_policies_stay_in_range() {
        testkit::forall("kpolicy-range", |g: &mut Gen| {
            let d = g.usize_in(1, 5000);
            let ratio = g.f32_in(1e-4, 1.0) as f64;
            let spec = match g.usize_in(0, 2) {
                0 => KSchedule::Const(if g.bool() { Some(ratio) } else { None }),
                1 => KSchedule::Warmup {
                    from: g.f32_in(1e-3, 1.0) as f64,
                    to: g.f32_in(1e-4, 1.0) as f64,
                    epochs: g.usize_in(1, 4),
                },
                _ => KSchedule::Adaptive {
                    delta: g.f32_in(0.1, 1.0) as f64,
                },
            };
            let mut sched = Scheduler::for_run(&spec, ratio, g.usize_in(1, 20), d);
            let mut rng = Pcg64::seed(g.rng.next_u64());
            for step in 0..30 {
                let plan = sched.plan(step);
                if plan.k < 1 || plan.k > d {
                    return Err(format!("{}: step {step} k {} ∉ [1, {d}]", sched.name(), plan.k));
                }
                let want = plan.k as f64 / d as f64;
                if (plan.density - want).abs() > 1e-12 {
                    return Err(format!("density {} != k/d {want}", plan.density));
                }
                if sched.wants_feedback() {
                    let u: Vec<f32> =
                        (0..d.min(256)).map(|_| rng.next_gaussian() as f32).collect();
                    sched.observe(step, &feedback_histogram(&u));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fold_is_identity_for_one_worker_and_sums_counts() {
        let mut rng = Pcg64::seed(11);
        let u: Vec<f32> = (0..4000).map(|_| rng.next_gaussian() as f32).collect();
        let h = feedback_histogram(&u);
        let folded = fold_feedback_histograms(std::slice::from_ref(&h));
        assert_eq!(folded.counts, h.counts, "one-worker fold must be the identity");
        assert_eq!(folded.total, h.total);
        assert_eq!(folded.hi.to_bits(), h.hi.to_bits());
        // Multi-worker: common span is the max, totals add.
        let v: Vec<f32> = (0..4000).map(|_| (2.0 * rng.next_gaussian()) as f32).collect();
        let h2 = feedback_histogram(&v);
        let folded2 = fold_feedback_histograms(&[h.clone(), h2.clone()]);
        assert_eq!(folded2.total, h.total + h2.total);
        assert_eq!(folded2.hi.to_bits(), h.hi.max(h2.hi).to_bits());
    }

    #[test]
    fn folded_feedback_is_not_dominated_by_worker0() {
        // The worker-0 bias regression: rank 0 holds a pathologically
        // spiky residual shard (10 huge coordinates), ranks 1..3 hold
        // ordinary spread-out gaussians. Observing worker 0 alone
        // collapses k to ~10 for the *whole cluster*; the rank-order fold
        // sees the other three shards' energy and keeps k three orders of
        // magnitude larger.
        let d = 20_000;
        let mut spiky = vec![1e-4f32; d];
        for i in 0..10 {
            spiky[i * 7] = 100.0;
        }
        let mut rng = Pcg64::seed(13);
        let others: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect();

        let mut biased = AdaptiveMass::new(d, 0.9, 0.001);
        biased.observe(0, &feedback_histogram(&spiky)); // the old behaviour
        let k_biased = biased.k_for_step(1);
        assert!(k_biased <= 200, "worker-0-only k {k_biased} should be tiny");

        let mut hists = vec![feedback_histogram(&spiky)];
        hists.extend(others.iter().map(|u| feedback_histogram(u)));
        let mut folded = AdaptiveMass::new(d, 0.9, 0.001);
        folded.observe(0, &fold_feedback_histograms(&hists));
        let k_folded = folded.k_for_step(1);
        assert!(
            k_folded > 50 * k_biased.max(1),
            "folded k {k_folded} must not be dominated by worker 0's spike (biased k {k_biased})"
        );
    }

    #[test]
    fn density_trace_shapes() {
        let spec = KSchedule::parse("warmup:0.016..0.001,epochs=2").unwrap();
        let trace = density_trace(&spec, 0.001, 3, 12);
        assert_eq!(trace.len(), 12);
        assert!((trace[0] - 0.016).abs() < 1e-12);
        for t in 1..12 {
            assert!(trace[t] <= trace[t - 1] + 1e-15, "not non-increasing at {t}");
        }
        assert!((trace[6] - 0.001).abs() < 1e-12, "post-warmup density");
        // Const and adaptive traces are flat at the base density.
        for spec in [KSchedule::Const(None), KSchedule::Adaptive { delta: 0.9 }] {
            let tr = density_trace(&spec, 0.002, 5, 4);
            assert!(tr.iter().all(|&r| (r - 0.002).abs() < 1e-15));
        }
        let explicit = density_trace(&KSchedule::Const(Some(0.01)), 0.002, 5, 2);
        assert!((explicit[0] - 0.01).abs() < 1e-15);
    }

    #[test]
    fn scheduler_clamps_degenerate_dims() {
        // d = 1: every schedule must resolve k = 1.
        for spec in [
            KSchedule::Const(Some(0.0001)),
            KSchedule::Warmup { from: 1.0, to: 0.001, epochs: 1 },
            KSchedule::Adaptive { delta: 0.5 },
        ] {
            let mut s = Scheduler::for_run(&spec, 0.001, 10, 1);
            assert_eq!(s.plan(0).k, 1);
            assert_eq!(s.plan(0).density, 1.0);
        }
    }
}
