//! Theorem 1 numerics (Fig. 5): compare, over a k-sweep,
//!
//! * the exact ratio `‖u − Top_k(u)‖² / ‖u‖²`,
//! * the classical bound `1 − k/d` (tight only for Rand_k),
//! * the paper's bound `(1 − k/d)²` (Theorem 1, for bell-shaped u).

use crate::util::json::Json;

/// One point of the Fig. 5 sweep.
#[derive(Debug, Clone)]
pub struct BoundPoint {
    pub k: usize,
    pub d: usize,
    pub exact: f64,
    pub classical: f64, // 1 - k/d
    pub ours: f64,      // (1 - k/d)^2
}

impl BoundPoint {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("k", Json::from(self.k))
            .set("d", Json::from(self.d))
            .set("exact", Json::from(self.exact))
            .set("classical", Json::from(self.classical))
            .set("ours", Json::from(self.ours));
        o
    }
}

/// Exact residual-energy ratio of Top_k on `u`: Σ_{i>k} π(i)² / Σ π(i)²
/// computed by sorting magnitudes (the definitional form, Eq. 5).
pub fn exact_topk_ratio(u: &[f32], k: usize) -> f64 {
    let d = u.len();
    if k >= d {
        return 0.0;
    }
    let mut mags: Vec<f64> = u.iter().map(|&v| (v as f64) * (v as f64)).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f64 = mags.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    let tail: f64 = mags[k..].iter().sum();
    tail / total
}

/// Sweep k over `ks` for a fixed vector, producing Fig. 5's three series.
pub fn bound_sweep(u: &[f32], ks: &[usize]) -> Vec<BoundPoint> {
    let d = u.len();
    // Sort once, reuse the prefix sums for every k.
    let mut mags: Vec<f64> = u.iter().map(|&v| (v as f64) * (v as f64)).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f64 = mags.iter().sum();
    let mut prefix = Vec::with_capacity(d + 1);
    let mut acc = 0.0;
    prefix.push(0.0);
    for &m in &mags {
        acc += m;
        prefix.push(acc);
    }
    ks.iter()
        .map(|&k| {
            let kk = k.min(d);
            let exact = if total == 0.0 {
                0.0
            } else {
                (total - prefix[kk]) / total
            };
            let f = 1.0 - kk as f64 / d as f64;
            BoundPoint {
                k,
                d,
                exact,
                classical: f,
                ours: f * f,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    fn gaussian_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        (0..d).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn fig5_ordering_holds_on_gaussian() {
        // exact ≤ (1−k/d)² ≤ (1−k/d), strictly for 0 < k < d on Gaussians.
        let u = gaussian_vec(100_000, 50);
        let ks: Vec<usize> = (1..=20).map(|i| i * 2500).collect();
        for p in bound_sweep(&u, &ks) {
            assert!(
                p.exact <= p.ours + 1e-12,
                "k={}: exact {} > ours {}",
                p.k,
                p.exact,
                p.ours
            );
            assert!(p.ours <= p.classical + 1e-12);
            if p.k > 0 && p.k < p.d {
                assert!(p.exact < p.ours, "bound should be strict at k={}", p.k);
            }
        }
    }

    #[test]
    fn sweep_matches_direct_computation() {
        let u = gaussian_vec(5000, 51);
        let ks = [1usize, 10, 100, 1000, 4999, 5000];
        let sweep = bound_sweep(&u, &ks);
        for (p, &k) in sweep.iter().zip(&ks) {
            let direct = exact_topk_ratio(&u, k);
            assert!((p.exact - direct).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn boundary_values() {
        let u = gaussian_vec(100, 52);
        assert_eq!(exact_topk_ratio(&u, 100), 0.0);
        assert!(exact_topk_ratio(&u, 0) > 0.999);
        let zero = vec![0.0f32; 10];
        assert_eq!(exact_topk_ratio(&zero, 5), 0.0);
    }

    /// Theorem 1 across the bell-shaped distribution zoo (Gaussian,
    /// Laplace, logistic): exact ≤ (1 − k/d)².
    #[test]
    fn prop_theorem1_bell_shapes() {
        testkit::forall("theorem1-bell", |g: &mut Gen| {
            let d = g.usize_in(1000, 50_000);
            let k = g.usize_in(1, d / 2);
            let u = match g.usize_in(0, 2) {
                0 => {
                    let sigma = g.f32_in(0.01, 5.0);
                    g.gaussian_vec(d, 0.0, sigma)
                }
                1 => {
                    let b = g.f64_in(0.01, 3.0);
                    let mut rng = Pcg64::seed(g.rng.next_u64());
                    (0..d).map(|_| rng.next_laplace(0.0, b) as f32).collect()
                }
                _ => {
                    let s = g.f64_in(0.01, 3.0);
                    let mut rng = Pcg64::seed(g.rng.next_u64());
                    (0..d).map(|_| rng.next_logistic(0.0, s) as f32).collect()
                }
            };
            let exact = exact_topk_ratio(&u, k);
            let ours = (1.0 - k as f64 / d as f64).powi(2);
            if exact > ours + 1e-9 {
                return Err(format!("d={d} k={k}: exact {exact} > (1-k/d)² {ours}"));
            }
            Ok(())
        });
    }

    /// The premise matters: a *uniform-magnitude* vector (all |u_i| equal)
    /// violates (1−k/d)² — its exact ratio is exactly 1 − k/d. This is why
    /// the theorem needs the bell-shape assumption.
    #[test]
    fn uniform_magnitude_saturates_classical_bound() {
        let d = 10_000;
        let u = vec![1.0f32; d];
        let k = 1000;
        let exact = exact_topk_ratio(&u, k);
        let classical = 1.0 - k as f64 / d as f64;
        let ours = classical * classical;
        assert!((exact - classical).abs() < 1e-9);
        assert!(exact > ours, "premise violation must break the tight bound");
    }
}
