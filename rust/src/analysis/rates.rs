//! Theorem 2 harness: EF-SGD convergence-rate ordering on analytically
//! tractable problems.
//!
//! Theorem 2 (via Karimireddy et al.) says error-feedback SGD with a
//! δ-contractive compressor needs `T ≥ O(1/δ²)` iterations before the
//! vanilla-SGD rate dominates. With the paper's bound δ_top = (2kd−k²)/d²
//! vs the classical δ = k/d, Top_k's predicted iteration threshold is
//! `O(c⁴/(2c−1)²)` vs Rand_k's `O(c²)` with c = d/k — i.e. Top_k
//! converges like Dense long before Rand_k does. This module measures
//! iterations-to-ε on noisy quadratic and logistic-regression objectives
//! and checks that empirical ordering.

use crate::compress::{Compressor, Workspace};
use crate::error_feedback::ResidualStore;
use crate::stats::rng::Pcg64;

/// A smooth objective with stochastic gradients.
pub trait Objective {
    fn dim(&self) -> usize;
    /// Stochastic gradient at x (adds sampling noise via rng).
    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]);
    /// Exact full gradient squared norm (convergence criterion).
    fn full_grad_norm_sq(&self, x: &[f32]) -> f64;
}

/// Noisy convex quadratic: f(x) = ½ Σ a_i x_i² with a log-spaced spectrum
/// (condition number `kappa`); stochastic gradient adds N(0, noise²).
pub struct Quadratic {
    pub a: Vec<f32>,
    pub noise: f32,
}

impl Quadratic {
    pub fn new(d: usize, kappa: f64, noise: f32) -> Quadratic {
        // Eigenvalues log-spaced in [1/kappa, 1].
        let a = (0..d)
            .map(|i| {
                let t = i as f64 / (d - 1).max(1) as f64;
                (kappa.powf(-(1.0 - t))) as f32
            })
            .collect();
        Quadratic { a, noise }
    }
}

impl Objective for Quadratic {
    fn dim(&self) -> usize {
        self.a.len()
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) {
        for ((o, &xi), &ai) in out.iter_mut().zip(x).zip(&self.a) {
            *o = ai * xi + self.noise * rng.next_gaussian() as f32;
        }
    }

    fn full_grad_norm_sq(&self, x: &[f32]) -> f64 {
        x.iter()
            .zip(&self.a)
            .map(|(&xi, &ai)| ((ai * xi) as f64).powi(2))
            .sum()
    }
}

/// ℓ2-regularized logistic regression on a fixed synthetic design matrix.
pub struct Logistic {
    pub xs: Vec<Vec<f32>>, // n × d
    pub ys: Vec<f32>,      // ±1
    pub lambda: f32,
    pub batch: usize,
}

impl Logistic {
    pub fn synthetic(n: usize, d: usize, seed: u64) -> Logistic {
        let mut rng = Pcg64::seed(seed);
        let w_true: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            let z: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum();
            let p = 1.0 / (1.0 + (-z as f64).exp());
            let y = if rng.next_f64() < p { 1.0 } else { -1.0 };
            xs.push(x);
            ys.push(y);
        }
        Logistic {
            xs,
            ys,
            lambda: 1e-3,
            batch: 16,
        }
    }

    fn grad_on(&self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        out.iter_mut().for_each(|o| *o = 0.0);
        for &i in idx {
            let xi = &self.xs[i];
            let z: f32 = xi.iter().zip(x).map(|(a, b)| a * b).sum();
            let margin = self.ys[i] * z;
            let s = (1.0 / (1.0 + (margin as f64).exp())) as f32; // σ(−m)
            let coef = -self.ys[i] * s;
            for (o, &v) in out.iter_mut().zip(xi) {
                *o += coef * v;
            }
        }
        let inv = 1.0 / idx.len().max(1) as f32;
        for (o, &w) in out.iter_mut().zip(x) {
            *o = *o * inv + self.lambda * w;
        }
    }
}

impl Objective for Logistic {
    fn dim(&self) -> usize {
        self.xs[0].len()
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) {
        let idx: Vec<usize> = (0..self.batch)
            .map(|_| rng.next_below(self.xs.len() as u64) as usize)
            .collect();
        self.grad_on(x, &idx, out);
    }

    fn full_grad_norm_sq(&self, x: &[f32]) -> f64 {
        let mut g = vec![0.0f32; self.dim()];
        let all: Vec<usize> = (0..self.xs.len()).collect();
        self.grad_on(x, &all, &mut g);
        crate::stats::norm2_sq(&g)
    }
}

/// Result of one EF-SGD run.
#[derive(Debug, Clone)]
pub struct RateResult {
    pub iterations: usize,
    pub reached_eps: bool,
    pub final_grad_norm_sq: f64,
    /// ‖∇f‖² trajectory sampled every `sample_every`.
    pub trajectory: Vec<f64>,
}

/// Run single-worker EF-SGD with the given compressor at a fixed `k`
/// until ‖∇f(x)‖² ≤ eps or max_iters. (Single worker isolates the
/// *compressor's* effect, which is what Theorem 2 bounds; per-step k
/// scheduling lives in the trainer.)
#[allow(clippy::too_many_arguments)]
pub fn run_ef_sgd(
    obj: &dyn Objective,
    comp: &mut dyn Compressor,
    k: usize,
    lr: f32,
    eps: f64,
    max_iters: usize,
    seed: u64,
    sample_every: usize,
) -> RateResult {
    let d = obj.dim();
    let mut x = vec![0.5f32; d]; // deterministic non-optimal start
    let mut rng = Pcg64::seed(seed);
    let mut store = ResidualStore::new(d);
    let mut ws = Workspace::new();
    let mut g = vec![0.0f32; d];
    let mut traj = Vec::new();
    for t in 0..max_iters {
        if t % sample_every == 0 {
            let n = obj.full_grad_norm_sq(&x);
            traj.push(n);
            if n <= eps {
                return RateResult {
                    iterations: t,
                    reached_eps: true,
                    final_grad_norm_sq: n,
                    trajectory: traj,
                };
            }
        }
        obj.stoch_grad(&x, &mut rng, &mut g);
        let sent = store.step(&g, comp, k, &mut ws);
        for (&i, &v) in sent.indices.iter().zip(&sent.values) {
            x[i as usize] -= lr * v;
        }
    }
    let n = obj.full_grad_norm_sq(&x);
    RateResult {
        iterations: max_iters,
        reached_eps: n <= eps,
        final_grad_norm_sq: n,
        trajectory: traj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Dense, RandK, TopK};

    #[test]
    fn quadratic_grad_consistency() {
        let q = Quadratic::new(16, 10.0, 0.0);
        let x = vec![1.0f32; 16];
        let mut rng = Pcg64::seed(1);
        let mut g = vec![0.0f32; 16];
        q.stoch_grad(&x, &mut rng, &mut g);
        // noise = 0 ⇒ stochastic == exact
        let n: f64 = g.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((n - q.full_grad_norm_sq(&x)).abs() < 1e-9);
    }

    #[test]
    fn dense_converges_on_quadratic() {
        let q = Quadratic::new(100, 10.0, 0.001);
        let mut comp = Dense;
        let r = run_ef_sgd(&q, &mut comp, 100, 0.5, 1e-4, 20_000, 7, 100);
        assert!(r.reached_eps, "dense EF-SGD should converge: {r:?}");
    }

    #[test]
    fn theorem2_ordering_topk_beats_randk() {
        // Theorem 2's δ enters the *transient* term 4L²G²(1−δ)/(δ²(T+1)):
        // with δ_top = (2kd−k²)/d² ≫ δ_rand = k/d, Top_k (a) burns off its
        // transient far earlier and (b) tolerates a larger learning rate.
        // Both effects are measured here on the noisy quadratic.
        let d = 500;
        let k = 25; // c = d/k = 20
        let q = Quadratic::new(d, 20.0, 0.001);

        // (a) Early-phase gap at lr = 0.05 (stable for both): after 200
        // iterations Top_k's full-gradient norm is orders of magnitude
        // below Rand_k's.
        let mut topk = TopK::new();
        let rt = run_ef_sgd(&q, &mut topk, k, 0.05, 0.0, 400, 11, 200);
        let mut randk = RandK::new(13);
        let rr = run_ef_sgd(&q, &mut randk, k, 0.05, 0.0, 400, 11, 200);
        let (gt, gr) = (rt.trajectory[1], rr.trajectory[1]);
        assert!(
            gt * 5.0 < gr,
            "at iter 200, topk {gt:.3e} should be ≪ randk {gr:.3e}"
        );

        // (b) Stability at lr = 0.1: Top_k descends monotonically into the
        // noise floor while Rand_k's delayed updates blow the transient up
        // by orders of magnitude above f(x₀)'s gradient norm.
        let mut topk = TopK::new();
        let rt = run_ef_sgd(&q, &mut topk, k, 0.1, 0.0, 4000, 11, 200);
        let mut randk = RandK::new(13);
        let rr = run_ef_sgd(&q, &mut randk, k, 0.1, 0.0, 4000, 11, 200);
        let peak = |t: &[f64]| t.iter().cloned().fold(0.0, f64::max);
        let start = rt.trajectory[0];
        assert!(
            peak(&rt.trajectory) <= start * 1.01,
            "topk transient should never exceed the initial gradient norm"
        );
        assert!(
            peak(&rr.trajectory[1..]) > start,
            "randk transient should overshoot at this lr (got peak {:.3e} vs start {start:.3e})",
            peak(&rr.trajectory[1..])
        );
        assert!(rt.final_grad_norm_sq < 1e-4, "topk should still converge");
    }

    #[test]
    fn logistic_synthetic_learnable() {
        let l = Logistic::synthetic(200, 20, 3);
        let mut comp = TopK::new();
        let r = run_ef_sgd(&l, &mut comp, 5, 0.5, 5e-3, 30_000, 17, 100);
        // Gradient norm should drop substantially from the start.
        assert!(
            r.final_grad_norm_sq < r.trajectory[0] * 0.05,
            "no progress: {} -> {}",
            r.trajectory[0],
            r.final_grad_norm_sq
        );
    }

    #[test]
    fn trajectory_sampled() {
        let q = Quadratic::new(10, 2.0, 0.0);
        let mut comp = Dense;
        let r = run_ef_sgd(&q, &mut comp, 10, 0.1, 0.0, 1000, 5, 100);
        assert_eq!(r.trajectory.len(), 10);
    }
}
