//! Fig. 3: the shape of π²(i), where π is the descending sort of
//! |u|/‖u‖∞. Theorem 1's geometric argument needs two empirical facts for
//! bell-shaped u:
//!
//! 1. π²(i) is (approximately) convex in i, and
//! 2. π²(i) lies below the reference line y = 1 − i/d.
//!
//! This module computes the curve and both diagnostics so the premise can
//! be *checked*, not assumed, on every gradient the trainer captures.

use crate::util::json::Json;

/// Compute π²: descending-sorted squared magnitudes normalized by the max.
pub fn pi_squared(u: &[f32]) -> Vec<f64> {
    let mut v: Vec<f64> = u.iter().map(|&x| (x as f64) * (x as f64)).collect();
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let max = v.first().copied().unwrap_or(0.0);
    if max > 0.0 {
        let inv = 1.0 / max;
        v.iter_mut().for_each(|x| *x *= inv);
    }
    v
}

/// Diagnostics of the Theorem 1 premise on one vector.
#[derive(Debug, Clone)]
pub struct PiCurveCheck {
    /// Fraction of interior points violating discrete convexity
    /// (π²(i−1) + π²(i+1) ≥ 2π²(i), with tolerance).
    pub convexity_violation_frac: f64,
    /// Fraction of points above the reference line y = 1 − i/d.
    pub above_line_frac: f64,
    /// Max amount by which the curve exceeds the line (0 if never).
    pub max_excess: f64,
}

impl PiCurveCheck {
    /// Evaluate the premise on a (sub-sampled) π² curve. `stride` > 1
    /// subsamples for large d; convexity is then checked on the coarse
    /// grid, which is what Fig. 3 plots anyway.
    pub fn evaluate(pi2: &[f64], stride: usize) -> PiCurveCheck {
        let d = pi2.len();
        let stride = stride.max(1);
        let pts: Vec<(usize, f64)> = (0..d).step_by(stride).map(|i| (i, pi2[i])).collect();
        let n = pts.len();
        let mut conv_bad = 0usize;
        for w in pts.windows(3) {
            let (_, a) = w[0];
            let (_, b) = w[1];
            let (_, c) = w[2];
            // Relative tolerance: in the near-flat tail, sampling noise
            // makes a+c ≈ 2b up to a small relative wobble; Fig. 3 plots
            // the same sub-sampled curve, which looks smooth at this
            // granularity.
            if a + c < 2.0 * b * (1.0 - 0.02) - 1e-12 {
                conv_bad += 1;
            }
        }
        let mut above = 0usize;
        let mut max_excess = 0.0f64;
        for &(i, y) in &pts {
            let line = 1.0 - i as f64 / d as f64;
            if y > line + 1e-12 {
                above += 1;
                max_excess = max_excess.max(y - line);
            }
        }
        PiCurveCheck {
            convexity_violation_frac: conv_bad as f64 / (n.saturating_sub(2)).max(1) as f64,
            above_line_frac: above as f64 / n.max(1) as f64,
            max_excess,
        }
    }

    /// The paper's premise "π² is convex and less than the line" with
    /// sampling-noise tolerance.
    pub fn premise_holds(&self) -> bool {
        self.convexity_violation_frac < 0.05 && self.above_line_frac < 0.01
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "convexity_violation_frac",
            Json::from(self.convexity_violation_frac),
        )
        .set("above_line_frac", Json::from(self.above_line_frac))
        .set("max_excess", Json::from(self.max_excess));
        o
    }
}

/// Fig. 3 series generator: π² of a Gaussian(0, σ²) vector of dimension d
/// plus the reference line, sub-sampled to `points` x-positions.
pub fn fig3_series(d: usize, sigma: f64, seed: u64, points: usize) -> Vec<(f64, f64, f64)> {
    let mut rng = crate::stats::rng::Pcg64::seed(seed);
    let u: Vec<f32> = (0..d).map(|_| (sigma * rng.next_gaussian()) as f32).collect();
    let pi2 = pi_squared(&u);
    let stride = (d / points.max(1)).max(1);
    (0..d)
        .step_by(stride)
        .map(|i| {
            let x = i as f64 / d as f64;
            (x, pi2[i], 1.0 - x)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    #[test]
    fn pi_squared_sorted_and_normalized() {
        let u = vec![3.0f32, -1.0, 2.0, 0.0];
        let p = pi_squared(&u);
        assert_eq!(p[0], 1.0); // 9/9
        assert!((p[1] - 4.0 / 9.0).abs() < 1e-12);
        assert!(p.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn premise_holds_for_gaussian_100k() {
        // The paper's exact Fig. 3 setting: d = 100,000, σ = 1.
        let mut rng = Pcg64::seed(60);
        let u: Vec<f32> = (0..100_000).map(|_| rng.next_gaussian() as f32).collect();
        let pi2 = pi_squared(&u);
        let check = PiCurveCheck::evaluate(&pi2, 100);
        assert!(
            check.premise_holds(),
            "premise should hold for N(0,1): {check:?}"
        );
        assert_eq!(check.above_line_frac, 0.0, "π² must stay below 1 − i/d");
    }

    #[test]
    fn premise_fails_for_uniform_magnitudes() {
        // All-equal magnitudes: π² ≡ 1, far above the line — the
        // counterexample that motivates the bell-shape assumption.
        let u = vec![1.0f32; 1000];
        let pi2 = pi_squared(&u);
        let check = PiCurveCheck::evaluate(&pi2, 1);
        assert!(!check.premise_holds());
        assert!(check.above_line_frac > 0.9);
    }

    #[test]
    fn fig3_series_shape() {
        let s = fig3_series(10_000, 1.0, 61, 100);
        assert!(s.len() >= 100);
        // Curve below line everywhere except i=0 (both = 1).
        for &(x, y, line) in &s[1..] {
            assert!(y <= line + 1e-12, "x={x}: π²={y} line={line}");
        }
    }

    #[test]
    fn zero_vector_is_flat() {
        let p = pi_squared(&[0.0f32; 10]);
        assert!(p.iter().all(|&x| x == 0.0));
    }
}
