//! Analysis toolkit for the paper's theory experiments:
//!
//! * [`bounds`] — exact `‖u − Top_k(u)‖²/‖u‖²` vs the classical (1 − k/d)
//!   bound vs the paper's (1 − k/d)² bound (Theorem 1, Fig. 5).
//! * [`pi_curve`] — the sorted-normalized-magnitude curve π²(i) and its
//!   convexity/below-reference-line diagnostics (Fig. 3).
//! * [`rates`] — convergence-rate harness on analytically tractable
//!   problems (Theorem 2's O(1/δ²) iteration-threshold ordering).

pub mod bounds;
pub mod pi_curve;
pub mod rates;

pub use bounds::{bound_sweep, exact_topk_ratio, BoundPoint};
pub use pi_curve::{pi_squared, PiCurveCheck};
