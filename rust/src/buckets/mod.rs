//! Gradient bucketing and the compute/communication-overlap pipeline.
//!
//! The paper's Table 2 / Fig. 4 argument is a systems-balance one: TopK-SGD
//! only scales when selection + communication hide behind compute. Real DDP
//! stacks achieve that by partitioning the flattened gradient into buckets
//! and overlapping each bucket's exchange with the next bucket's local work
//! (Horovod tensor fusion, PyTorch DDP gradient buckets, and the pipelined
//! sparse aggregation of Shi et al. 2019). This module provides the two
//! pieces the trainer and the netsim share:
//!
//! * [`BucketSchedule`] — a partition of the flat `d`-dimensional gradient
//!   into contiguous, layer-aligned or fixed-byte buckets, each carrying its
//!   own slice of the error-feedback residual and its own per-bucket `k`.
//! * [`run_pipelined`] — a two-stage, double-buffered producer/consumer
//!   pipeline: the producer compresses bucket `i + 1` on its own thread
//!   while the consumer runs the ring exchange for bucket `i`.
//!   [`run_pipelined_return`] adds a **payload return channel** so spent
//!   O(k) payload buffers flow back to the producer for recycling — the
//!   bucketed twin of the monolithic path's workspace recycling.
//!
//! ## Per-bucket `k` apportionment
//!
//! The global budget `k` is split across buckets proportionally to bucket
//! size with the largest-remainder method ([`apportion_k`]): bucket `b` of
//! `d_b` elements gets `⌊k·d_b/d⌋` slots, and the leftover slots go to the
//! buckets with the largest fractional remainders (ties broken by lower
//! bucket index). This follows the paper's per-layer density observation —
//! top-k mass is spread across layers roughly in proportion to layer size —
//! and guarantees `Σ_b k_b == min(k, d)` exactly, with `k_b ≤ d_b` per
//! bucket, so the wire budget of a bucketed step equals the monolithic one.
//!
//! The `bucket_apportion = mass` knob swaps the size weights for worker
//! 0's per-bucket ‖u‖² shares ([`BucketSchedule::apportion_k_by_mass`],
//! built on [`apportion_k_weighted`]) — the Adaptive Top-K observation
//! that layers with more gradient energy deserve more of the budget. The
//! Σ/cap guarantees are identical, so the wire budget never changes, only
//! its distribution.
//!
//! ## The determinism guarantee under pipelining
//!
//! Bucketed training is **bit-identical** between the serial bucket loop
//! and the pipelined path, by construction:
//!
//! 1. buckets are disjoint, contiguous slices, so the per-bucket
//!    error-feedback update `ε_b ← u_b − s_b` touches state no other bucket
//!    reads;
//! 2. the producer emits buckets in index order (a single thread), and the
//!    consumer applies aggregates in arrival order over a FIFO channel, so
//!    the schedule seen by every stage is `0, 1, …, B−1` in both modes;
//! 3. each bucket's aggregation runs through the same
//!    [`Collectives`](crate::collectives::Collectives) engine either way,
//!    and those engines are themselves bit-identical across serial/threaded
//!    (see the `collectives` module docs).
//!
//! `tests/bucket_equivalence.rs` locks the invariant end to end for every
//! operator.

use crate::tensor::Layout;

/// One bucket of the flat gradient: the contiguous range `[lo, hi)` and its
/// apportioned share of the global sparsification budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketSpec {
    /// Position of this bucket in the schedule (0-based).
    pub index: usize,
    /// Inclusive start offset into the flat gradient.
    pub lo: usize,
    /// Exclusive end offset.
    pub hi: usize,
    /// This bucket's share of the global k (may be 0 for tiny buckets).
    pub k: usize,
}

impl BucketSpec {
    /// Number of gradient elements in this bucket.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// A partition of the flat `d`-dimensional gradient into contiguous
/// non-empty buckets covering `[0, d)` exactly, with per-bucket `k`
/// apportioned from the global budget (see the module docs). The specs
/// carry the apportionment of the *construction-time* k; when a
/// [`crate::schedule`] plan varies k between steps, the trainer
/// re-apportions per step via [`BucketSchedule::apportion_k`] over the
/// same bucket sizes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketSchedule {
    d: usize,
    specs: Vec<BucketSpec>,
    /// Cached bucket sizes (avoids rebuilding them for every per-step
    /// re-apportionment).
    sizes: Vec<usize>,
}

impl BucketSchedule {
    /// Single bucket covering the whole gradient (the monolithic baseline
    /// expressed in bucket form). `d == 0` yields an empty schedule.
    pub fn monolithic(d: usize, k: usize) -> BucketSchedule {
        Self::from_ranges(d, k, vec![(0, d)])
    }

    /// Layer-aligned buckets: one bucket per layer slice of `layout`
    /// (zero-size layers are skipped). This is the `buckets = layers` knob.
    pub fn from_layout(layout: &Layout, k: usize) -> BucketSchedule {
        let ranges: Vec<(usize, usize)> = layout
            .offsets
            .iter()
            .zip(&layout.sizes)
            .map(|(&o, &s)| (o, o + s))
            .collect();
        Self::from_ranges(layout.total(), k, ranges)
    }

    /// Fixed-byte buckets of `bytes` each (f32 elements, so `bytes / 4`
    /// elements per bucket, minimum 1); the trailing bucket may be smaller.
    /// This is the `buckets = bytes:N` knob.
    pub fn fixed_bytes(d: usize, bytes: usize, k: usize) -> BucketSchedule {
        let elems = (bytes / 4).max(1);
        let mut ranges = Vec::new();
        let mut lo = 0;
        while lo < d {
            let hi = (lo + elems).min(d);
            ranges.push((lo, hi));
            lo = hi;
        }
        Self::from_ranges(d, k, ranges)
    }

    /// Build from explicit ranges: empty ranges are dropped, the rest must
    /// tile `[0, d)` contiguously in order (debug-asserted), and the global
    /// `k` is apportioned across the survivors.
    fn from_ranges(d: usize, k: usize, ranges: Vec<(usize, usize)>) -> BucketSchedule {
        let ranges: Vec<(usize, usize)> = ranges.into_iter().filter(|(lo, hi)| hi > lo).collect();
        debug_assert!(
            {
                let mut cursor = 0;
                ranges.iter().all(|&(lo, hi)| {
                    let ok = lo == cursor && hi <= d;
                    cursor = hi;
                    ok
                }) && (cursor == d)
            },
            "bucket ranges must tile [0, {d}) contiguously"
        );
        let sizes: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
        let ks = apportion_k(&sizes, k);
        let specs = ranges
            .into_iter()
            .zip(ks)
            .enumerate()
            .map(|(index, ((lo, hi), k))| BucketSpec { index, lo, hi, k })
            .collect();
        BucketSchedule { d, specs, sizes }
    }

    /// Flat gradient dimension this schedule partitions.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of (non-empty) buckets.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// The bucket specs in schedule order.
    pub fn specs(&self) -> &[BucketSpec] {
        &self.specs
    }

    /// Per-bucket element counts in schedule order (the apportionment
    /// weights of the `size` mode and the [`ema_masses`] fallback target).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Sum of the per-bucket budgets (== `min(k, d)` by construction).
    pub fn total_k(&self) -> usize {
        self.specs.iter().map(|s| s.k).sum()
    }

    /// Re-apportion a *per-step* budget `k_t` across this schedule's
    /// buckets (largest-remainder over the cached sizes — the same
    /// function that filled the specs at construction, so a constant
    /// schedule reproduces `specs()[b].k` exactly). `Σ = min(k_t, d)`.
    pub fn apportion_k(&self, k_t: usize) -> Vec<usize> {
        apportion_k(&self.sizes, k_t)
    }

    /// Adaptive (Adaptive Top-K style) re-apportionment: split the
    /// per-step budget `k_t` proportionally to `per_bucket_mass` — the
    /// cluster's per-bucket error-compensated gradient energy
    /// (`Σ_w ‖u_{w,b}‖²` summed over all workers in rank order), one entry
    /// per schedule bucket — with the same largest-remainder rounding and
    /// per-bucket size caps as [`BucketSchedule::apportion_k`], so
    /// `Σ = min(k_t, d)` and `k_b ≤ d_b` always hold.
    ///
    /// Degenerate statistics fall back to the size-proportional split:
    /// a length mismatch, any non-finite mass, or total mass ≤ 0 (an
    /// all-zero gradient — nothing to steer by). The fallback keeps the
    /// wire budget intact on the steps where stats are absent.
    pub fn apportion_k_by_mass(&self, k_t: usize, per_bucket_mass: &[f64]) -> Vec<usize> {
        let degenerate = per_bucket_mass.len() != self.sizes.len()
            || per_bucket_mass.iter().any(|m| !m.is_finite() || *m < 0.0)
            || per_bucket_mass.iter().sum::<f64>() <= 0.0;
        if degenerate {
            return apportion_k(&self.sizes, k_t);
        }
        apportion_k_weighted(&self.sizes, per_bucket_mass, k_t)
    }
}

/// Split the global budget `k` across buckets of the given sizes with the
/// largest-remainder method: `k_b = ⌊k·d_b/d⌋` plus one extra slot for the
/// buckets with the largest remainders `(k·d_b) mod d` (ties → lower
/// index), capped at the bucket size. Zero-size buckets get 0.
///
/// Guarantees (property-tested in `tests/bucket_equivalence.rs`):
/// `Σ k_b == min(k, Σ d_b)`, `k_b ≤ d_b`, and `|k_b − k·d_b/d| ≤ 1` for
/// every uncapped bucket.
pub fn apportion_k(sizes: &[usize], k: usize) -> Vec<usize> {
    let d: usize = sizes.iter().sum();
    if d == 0 {
        return vec![0; sizes.len()];
    }
    let k = k.min(d);
    // Floor quotas (u128 intermediates: k·d_b can overflow u64 at large d).
    let mut ks: Vec<usize> = sizes
        .iter()
        .map(|&s| ((k as u128 * s as u128) / d as u128) as usize)
        .collect();
    let assigned: usize = ks.iter().sum();
    let mut leftover = k - assigned;
    if leftover == 0 {
        return ks;
    }
    // Largest fractional remainder first; ties broken by lower index so the
    // split is fully deterministic.
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by_key(|&i| {
        (
            std::cmp::Reverse((k as u128 * sizes[i] as u128) % d as u128),
            i,
        )
    });
    // Round-robin over the remainder order, skipping buckets already at
    // capacity. Terminates because Σ capacity = d ≥ k: while leftover > 0
    // some bucket has spare room, so every full pass makes progress.
    let mut cursor = 0;
    while leftover > 0 {
        let i = order[cursor % order.len()];
        if ks[i] < sizes[i] {
            ks[i] += 1;
            leftover -= 1;
        }
        cursor += 1;
    }
    ks
}

/// Largest-remainder apportionment over arbitrary non-negative f64
/// weights (the mass-proportional variant of [`apportion_k`]): bucket b
/// gets `⌊k·w_b/W⌋` slots (capped at its size), and leftover slots go to
/// the largest fractional remainders (ties → lower index), skipping full
/// buckets. Guarantees `Σ k_b == min(k, Σ d_b)` and `k_b ≤ d_b` for any
/// weight vector with `W > 0`; fully deterministic (f64 quotas are pure
/// arithmetic, ties break by index).
///
/// Callers must pre-screen degenerate weights
/// ([`BucketSchedule::apportion_k_by_mass`] falls back to the size split);
/// here `W ≤ 0` simply yields the zero assignment after the capacity
/// round-robin fills from bucket 0 — never a panic.
pub fn apportion_k_weighted(sizes: &[usize], weights: &[f64], k: usize) -> Vec<usize> {
    debug_assert_eq!(sizes.len(), weights.len());
    let d: usize = sizes.iter().sum();
    if d == 0 || sizes.is_empty() {
        return vec![0; sizes.len()];
    }
    let k = k.min(d);
    let total_w: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    // Zero/invalid total weight: all quotas 0, the round-robin below fills
    // the whole budget in index order (still exact and deterministic).
    let quota = |i: usize| -> f64 {
        let w = weights[i];
        if total_w > 0.0 && w.is_finite() && w > 0.0 {
            k as f64 * (w / total_w)
        } else {
            0.0
        }
    };
    let mut ks: Vec<usize> = (0..sizes.len())
        .map(|i| (quota(i).floor() as usize).min(sizes[i]))
        .collect();
    let mut assigned: usize = ks.iter().sum();
    // Paranoia against f64 rounding pushing Σ⌊quota⌋ past k: shave from
    // the highest-index non-empty assignment (unreachable in practice,
    // but the Σ == min(k, d) contract must hold unconditionally).
    while assigned > k {
        let i = ks.iter().rposition(|&x| x > 0).expect("assigned > k implies a non-zero entry");
        ks[i] -= 1;
        assigned -= 1;
    }
    let mut leftover = k - assigned;
    if leftover == 0 {
        return ks;
    }
    // Largest fractional remainder first; ties broken by lower index.
    // f64 bit order via total_cmp — deterministic across platforms.
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = quota(a) - quota(a).floor();
        let rb = quota(b) - quota(b).floor();
        rb.total_cmp(&ra).then(a.cmp(&b))
    });
    // Round-robin with capacity skip — terminates because Σ d_b = d ≥ k.
    let mut cursor = 0;
    while leftover > 0 {
        let i = order[cursor % order.len()];
        if ks[i] < sizes[i] {
            ks[i] += 1;
            leftover -= 1;
        }
        cursor += 1;
    }
    ks
}

/// Exponential-moving-average update of the per-bucket mass estimates the
/// `bucket_apportion = mass:ema=BETA` trainer mode steers by:
/// `m̄_b ← β·m̄_b + (1 − β)·m_b`. An empty (or wrong-length) `smoothed`
/// state seeds from the raw masses — step 0 of an EMA run therefore
/// apportions exactly like the unsmoothed mode.
///
/// A raw vector containing a non-finite entry (a diverging step producing
/// NaN/∞ norms) must not poison the smoothing state — but it must not
/// *freeze* it either: the old early-return meant a single bad step pinned
/// the smoothed shares forever, so every later step kept apportioning by a
/// stale snapshot no matter how the gradient distribution moved. Instead,
/// a degenerate step decays the state one EMA tick toward the neutral
/// **size-proportional** target `total · d_b / Σ d_b` (scale preserved so
/// recovery re-weights, not re-seeds; if the current total is itself
/// non-finite or non-positive, the target falls back to the raw sizes).
/// Repeated bad steps therefore converge to exactly the `size` apportion
/// mode — the fallback the trainer would use with no mass signal at all —
/// and one good step immediately starts pulling the state back.
pub fn ema_masses(smoothed: &mut Vec<f64>, raw: &[f64], sizes: &[usize], beta: f64) {
    debug_assert!((0.0..1.0).contains(&beta), "ema beta must be in [0, 1)");
    debug_assert_eq!(raw.len(), sizes.len(), "one size per bucket");
    let finite = raw.iter().all(|m| m.is_finite());
    if smoothed.len() != raw.len() {
        smoothed.clear();
        if finite {
            smoothed.extend_from_slice(raw);
        } else {
            // Nothing usable to seed from: start at the neutral target.
            smoothed.extend(sizes.iter().map(|&s| s as f64));
        }
        return;
    }
    if finite {
        for (s, &m) in smoothed.iter_mut().zip(raw) {
            *s = beta * *s + (1.0 - beta) * m;
        }
        return;
    }
    // Degenerate step: decay toward the size-proportional fallback.
    let total: f64 = smoothed.iter().sum();
    let dim: f64 = sizes.iter().map(|&s| s as f64).sum();
    let (scale, denom) = if total.is_finite() && total > 0.0 && dim > 0.0 {
        (total, dim)
    } else {
        (1.0, 1.0)
    };
    for (s, &sz) in smoothed.iter_mut().zip(sizes) {
        let target = scale * (sz as f64) / denom;
        // A non-finite state entry (hand-seeded by a caller) can't decay
        // arithmetically — snap it to the target outright.
        *s = if s.is_finite() { beta * *s + (1.0 - beta) * target } else { target };
    }
}

/// Two-stage, double-buffered pipeline: `produce(b)` runs on a dedicated
/// producer thread for `b = 0..n` in order, while `consume(b, item)` runs
/// on the calling thread in the same order. A rendezvous channel of depth 1
/// means at most one finished item waits while the next is being produced —
/// classic double buffering, so the producer works on bucket `i + 1` while
/// the consumer exchanges bucket `i`.
///
/// Determinism: both closures observe the exact sequence `0, 1, …, n − 1`,
/// so the result is bit-identical to the serial loop
/// `for b in 0..n { consume(b, produce(b)) }` whenever `produce` and
/// `consume` are deterministic functions of their own accumulated state —
/// the pipeline changes *when* work happens, never *what* happens.
///
/// This is the no-recycling convenience wrapper around
/// [`run_pipelined_return`]: consumed items are simply dropped.
pub fn run_pipelined<T, P, C>(n: usize, mut produce: P, mut consume: C)
where
    T: Send,
    P: FnMut(usize) -> T + Send,
    C: FnMut(usize, T),
{
    let (leftovers, _spawn_s) = run_pipelined_return(
        n,
        move |b, _spent: &mut Vec<T>| produce(b),
        move |b, item| {
            consume(b, item);
            None
        },
    );
    debug_assert!(leftovers.is_empty(), "drop-only consume returned payloads");
}

/// [`run_pipelined`] with a **payload return channel**: after `consume`
/// finishes with an item it may hand it back (`Some(spent)`), and the
/// spent items flow to the producer thread over a second channel. Before
/// producing bucket `b`, the producer drains everything that has arrived
/// into `spent` and passes it to `produce(b, &mut spent)` — the trainer's
/// producer recycles the O(k) payload buffers into the owning workers'
/// workspaces there, which is what makes the *bucketed* exchange
/// allocation-free in the steady state (the monolithic path already
/// recycles after its single collective).
///
/// Returned value: `(leftovers, producer_spawn_seconds)`. The leftovers
/// are the spent items the producer never saw (those of the final
/// buckets, returned after the producer finished); the caller recycles
/// them itself — they seed the free lists for the *next* step, so across
/// steps nothing is lost. The spawn time is the wall clock of creating
/// the producer thread — the per-step launch cost the trainer folds into
/// `StepRecord::spawn_or_dispatch_us` (and the cost the pooled pipeline
/// retires).
///
/// Determinism is unchanged from [`run_pipelined`]: recycling only moves
/// buffer *capacity* around (recycled buffers are cleared before reuse —
/// the [`crate::compress::Workspace`] contract), and the drain order can
/// therefore never influence numerics. Both closures still observe
/// buckets in the exact sequence `0, 1, …, n − 1`.
pub fn run_pipelined_return<T, P, C>(n: usize, produce: P, mut consume: C) -> (Vec<T>, f64)
where
    T: Send,
    P: FnMut(usize, &mut Vec<T>) -> T + Send,
    C: FnMut(usize, T) -> Option<T>,
{
    if n == 0 {
        return (Vec::new(), 0.0);
    }
    let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, T)>(1);
    let (return_tx, return_rx) = std::sync::mpsc::channel::<T>();
    let mut leftovers = Vec::new();
    let mut spawn_s = 0.0f64;
    std::thread::scope(|s| {
        let mut produce = produce;
        let t_spawn = std::time::Instant::now();
        let handle = s.spawn(move || {
            let mut spent: Vec<T> = Vec::new();
            for b in 0..n {
                while let Ok(item) = return_rx.try_recv() {
                    spent.push(item);
                }
                let item = produce(b, &mut spent);
                // A send error means the consumer side is gone (panicked);
                // stop producing and let the scope surface the panic.
                if tx.send((b, item)).is_err() {
                    break;
                }
            }
            // Anything produce() left in `spent` plus whatever is still in
            // flight goes back to the caller.
            (spent, return_rx)
        });
        spawn_s = t_spawn.elapsed().as_secs_f64();
        for _ in 0..n {
            let (b, item) = rx.recv().expect("pipeline producer hung up");
            if let Some(spent) = consume(b, item) {
                // The producer may already be past its last drain; the
                // leftover sweep below catches anything it missed.
                let _ = return_tx.send(spent);
            }
        }
        drop(return_tx);
        let (mut spent, return_rx) = handle.join().expect("pipeline producer panicked");
        leftovers.append(&mut spent);
        while let Ok(item) = return_rx.try_recv() {
            leftovers.push(item);
        }
    });
    (leftovers, spawn_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monolithic_is_one_bucket() {
        let s = BucketSchedule::monolithic(100, 7);
        assert_eq!(s.len(), 1);
        assert_eq!(s.specs()[0], BucketSpec { index: 0, lo: 0, hi: 100, k: 7 });
        assert_eq!(s.total_k(), 7);
        // d == 0: empty schedule, nothing to exchange.
        assert!(BucketSchedule::monolithic(0, 5).is_empty());
    }

    #[test]
    fn fixed_bytes_tiles_exactly() {
        // 10 elements in 16-byte (4-element) buckets: 4 + 4 + 2.
        let s = BucketSchedule::fixed_bytes(10, 16, 5);
        let sizes: Vec<usize> = s.specs().iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(s.total_k(), 5);
        let mut cursor = 0;
        for b in s.specs() {
            assert_eq!(b.lo, cursor);
            cursor = b.hi;
        }
        assert_eq!(cursor, 10);
        // bytes < 4 clamps to one element per bucket.
        assert_eq!(BucketSchedule::fixed_bytes(3, 1, 3).len(), 3);
    }

    #[test]
    fn layout_buckets_skip_empty_layers() {
        let mut l = Layout::new();
        l.push("w1", 6);
        l.push("empty", 0);
        l.push("b1", 2);
        let s = BucketSchedule::from_layout(&l, 4);
        assert_eq!(s.len(), 2);
        assert_eq!(s.specs()[0].len(), 6);
        assert_eq!(s.specs()[1].len(), 2);
        assert_eq!(s.total_k(), 4);
        // Proportional: the 6-element bucket gets 3, the 2-element one 1.
        assert_eq!(s.specs()[0].k, 3);
        assert_eq!(s.specs()[1].k, 1);
    }

    #[test]
    fn apportion_sums_and_caps() {
        assert_eq!(apportion_k(&[6, 2], 4), vec![3, 1]);
        // k > d clamps to d.
        assert_eq!(apportion_k(&[2, 2], 100), vec![2, 2]);
        // Zero-size buckets get 0; all-empty sums to 0.
        assert_eq!(apportion_k(&[0, 3, 0], 2), vec![0, 2, 0]);
        assert_eq!(apportion_k(&[0, 0], 5), vec![0, 0]);
        assert_eq!(apportion_k(&[], 5), Vec::<usize>::new());
        // k smaller than the bucket count: leftover slots go to the largest
        // remainders, lower index on ties.
        assert_eq!(apportion_k(&[1, 1, 1, 1], 2), vec![1, 1, 0, 0]);
    }

    #[test]
    fn apportion_is_deterministic_and_exact() {
        let sizes = vec![7, 0, 13, 1, 1, 512, 3];
        for k in 0..=537 {
            let ks = apportion_k(&sizes, k);
            assert_eq!(ks.iter().sum::<usize>(), k.min(537), "k={k}");
            for (b, (&kb, &db)) in ks.iter().zip(&sizes).enumerate() {
                assert!(kb <= db, "k={k} bucket {b}: {kb} > {db}");
            }
            assert_eq!(ks, apportion_k(&sizes, k), "k={k} not deterministic");
        }
    }

    #[test]
    fn per_step_reapportion_matches_construction() {
        let s = BucketSchedule::fixed_bytes(100, 32, 10);
        // Re-apportioning the construction-time k reproduces the specs.
        let base: Vec<usize> = s.specs().iter().map(|b| b.k).collect();
        assert_eq!(s.apportion_k(10), base);
        // A varying k_t still sums to min(k_t, d) with per-bucket caps.
        for k_t in [0usize, 1, 7, 50, 100, 1000] {
            let ks = s.apportion_k(k_t);
            assert_eq!(ks.iter().sum::<usize>(), k_t.min(100), "k_t={k_t}");
            for (kb, sp) in ks.iter().zip(s.specs()) {
                assert!(*kb <= sp.len());
            }
        }
    }

    #[test]
    fn pipeline_matches_serial_loop() {
        // Stateful producer and consumer: the pipeline must see the same
        // sequence and produce the same folds as the serial loop.
        for n in [0usize, 1, 2, 7, 32] {
            let mut produced = Vec::new();
            let mut folded = 0u64;
            run_pipelined(
                n,
                |b| {
                    // Deterministic per-bucket "work".
                    (b as u64 + 1) * (b as u64 + 1)
                },
                |b, item| {
                    produced.push(b);
                    folded = folded.wrapping_mul(31).wrapping_add(item);
                },
            );
            let want_order: Vec<usize> = (0..n).collect();
            assert_eq!(produced, want_order, "n={n}");
            let mut want_fold = 0u64;
            for b in 0..n {
                want_fold = want_fold
                    .wrapping_mul(31)
                    .wrapping_add((b as u64 + 1) * (b as u64 + 1));
            }
            assert_eq!(folded, want_fold, "n={n}");
        }
    }

    #[test]
    fn weighted_apportion_sums_caps_and_follows_mass() {
        let sizes = [8usize, 8, 8];
        // All the mass in bucket 1: it takes everything it can hold.
        let ks = apportion_k_weighted(&sizes, &[0.0, 10.0, 0.0], 6);
        assert_eq!(ks, vec![0, 6, 0]);
        // More mass than capacity spills over to the rest (round-robin in
        // remainder order, index ties upward).
        let ks = apportion_k_weighted(&sizes, &[0.0, 10.0, 0.0], 12);
        assert_eq!(ks.iter().sum::<usize>(), 12);
        assert_eq!(ks[1], 8);
        // Equal mass reduces to an even split.
        assert_eq!(apportion_k_weighted(&sizes, &[1.0, 1.0, 1.0], 6), vec![2, 2, 2]);
        // Exactness + caps + determinism over a k sweep.
        let w = [0.3, 5.0, 0.0, 2.2];
        let sz = [3usize, 10, 2, 5];
        for k in 0..=25 {
            let ks = apportion_k_weighted(&sz, &w, k);
            assert_eq!(ks.iter().sum::<usize>(), k.min(20), "k={k}");
            for (b, (&kb, &db)) in ks.iter().zip(&sz).enumerate() {
                assert!(kb <= db, "k={k} bucket {b}");
            }
            assert_eq!(ks, apportion_k_weighted(&sz, &w, k), "k={k} not deterministic");
        }
        // Degenerate inputs never panic.
        assert_eq!(apportion_k_weighted(&[], &[], 4), Vec::<usize>::new());
        assert_eq!(apportion_k_weighted(&[0, 0], &[1.0, 1.0], 3), vec![0, 0]);
    }

    #[test]
    fn mass_apportion_falls_back_to_size() {
        let s = BucketSchedule::fixed_bytes(16, 32, 4); // two 8-elem buckets
        let size_split = s.apportion_k(4);
        // Degenerate stats: wrong length, NaN, zero total → size split.
        assert_eq!(s.apportion_k_by_mass(4, &[1.0]), size_split);
        assert_eq!(s.apportion_k_by_mass(4, &[f64::NAN, 1.0]), size_split);
        assert_eq!(s.apportion_k_by_mass(4, &[0.0, 0.0]), size_split);
        assert_eq!(s.apportion_k_by_mass(4, &[-1.0, 2.0]), size_split);
        // Real mass steers the split but conserves the budget.
        let ks = s.apportion_k_by_mass(4, &[9.0, 1.0]);
        assert_eq!(ks.iter().sum::<usize>(), 4);
        assert!(ks[0] > ks[1]);
    }

    #[test]
    fn pipeline_return_channel_recycles_and_reports_leftovers() {
        // Items are Vec<u8> "payloads"; the producer reuses returned
        // buffers, and whatever it never saw comes back as leftovers.
        for n in [1usize, 2, 5, 17] {
            let mut consumed = Vec::new();
            let (leftovers, spawn_s) = run_pipelined_return(
                n,
                move |b, spent: &mut Vec<Vec<u8>>| {
                    let mut buf = spent.pop().unwrap_or_default();
                    spent.clear(); // producer contract: drain every drain-point
                    buf.clear();
                    buf.push(b as u8);
                    buf
                },
                |b, item| {
                    consumed.push((b, item[0]));
                    Some(item)
                },
            );
            // Every bucket consumed in order, payload intact.
            let want: Vec<(usize, u8)> = (0..n).map(|b| (b, b as u8)).collect();
            assert_eq!(consumed, want, "n={n}");
            // Every payload is either recycled by the producer or handed
            // back as a leftover — none silently dropped. (The producer
            // pops at most one buffer per bucket and clears the rest, so
            // we only assert the conservation bound.)
            assert!(!leftovers.is_empty(), "n={n}: final payloads must come back");
            assert!(leftovers.len() <= n, "n={n}");
            // A real producer thread was spawned and timed.
            assert!(spawn_s.is_finite() && spawn_s >= 0.0, "n={n}");
        }
    }

    #[test]
    fn ema_masses_seeds_smooths_and_reduces_thrash() {
        // Seeding: an empty state copies the raw masses (step 0 of an EMA
        // run apportions exactly like the unsmoothed mode).
        let mut s = Vec::new();
        ema_masses(&mut s, &[1.0, 9.0], &[64, 64], 0.9);
        assert_eq!(s, vec![1.0, 9.0]);
        // β = 0 tracks the raw masses exactly.
        let mut t = vec![5.0, 5.0];
        ema_masses(&mut t, &[1.0, 9.0], &[64, 64], 0.0);
        assert_eq!(t, vec![1.0, 9.0]);
        // Thrash reduction: alternating raw masses swing the per-bucket k
        // split bucket-to-bucket every step; the β = 0.9 EMA holds it
        // nearly constant. Measure total step-to-step k movement.
        let sizes = [64usize, 64];
        let sched = BucketSchedule::fixed_bytes(128, 256, 16);
        let raw_steps: Vec<[f64; 2]> =
            (0..20).map(|t| if t % 2 == 0 { [9.0, 1.0] } else { [1.0, 9.0] }).collect();
        let movement = |betas: f64| -> usize {
            let mut smoothed = Vec::new();
            let mut prev: Option<Vec<usize>> = None;
            let mut moved = 0;
            for raw in &raw_steps {
                ema_masses(&mut smoothed, raw, &sizes, betas);
                let ks = sched.apportion_k_by_mass(16, &smoothed);
                assert_eq!(ks.iter().sum::<usize>(), 16);
                for (kb, &db) in ks.iter().zip(&sizes) {
                    assert!(*kb <= db);
                }
                if let Some(p) = &prev {
                    moved += ks.iter().zip(p).map(|(a, b)| a.abs_diff(*b)).sum::<usize>();
                }
                prev = Some(ks);
            }
            moved
        };
        let raw_movement = movement(0.0);
        let smoothed_movement = movement(0.9);
        assert!(
            smoothed_movement * 4 < raw_movement,
            "ema did not damp thrash: {smoothed_movement} vs raw {raw_movement}"
        );
        // A non-finite raw step decays one EMA tick toward the
        // size-proportional split at the same total (total 6 over equal
        // sizes → target [3, 3]), instead of freezing the stale shares.
        let mut u = vec![2.0, 4.0];
        ema_masses(&mut u, &[f64::NAN, 1.0], &[64, 64], 0.5);
        assert_eq!(u, vec![0.5 * 2.0 + 0.5 * 3.0, 0.5 * 4.0 + 0.5 * 3.0]);
        // A schedule-length change re-seeds rather than zipping short.
        ema_masses(&mut u, &[1.0, 2.0, 3.0], &[32, 32, 32], 0.5);
        assert_eq!(u, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ema_masses_recovers_from_degenerate_steps() {
        // Regression for the PR-7 freeze bug: the old implementation
        // early-returned on any non-finite raw mass, so the smoothed
        // shares were pinned to the last good snapshot *forever* — a
        // single diverging step near t = 0 steered the apportionment for
        // the rest of the run. The fix decays toward the
        // size-proportional fallback, so a run of bad steps converges to
        // the `size` split and good steps re-steer immediately.
        let sizes = [96usize, 32];
        let mut smoothed = vec![120.0, 8.0]; // heavily skewed good state
        let total0: f64 = smoothed.iter().sum();
        for _ in 0..64 {
            ema_masses(&mut smoothed, &[f64::INFINITY, f64::NAN], &sizes, 0.5);
        }
        // Converged (up to rounding) to total · d_b / Σ d_b — scale kept.
        let total: f64 = smoothed.iter().sum();
        assert!((total - total0).abs() < 1e-9 * total0, "scale drifted: {total}");
        assert!((smoothed[0] - total0 * 0.75).abs() < 1e-6);
        assert!((smoothed[1] - total0 * 0.25).abs() < 1e-6);
        // The downstream apportionment now matches the size split exactly.
        let sched = BucketSchedule::fixed_bytes(128, 384, 16);
        assert_eq!(sched.apportion_k_by_mass(16, &smoothed), sched.apportion_k(16));
        // A good step immediately pulls the state toward the fresh signal
        // (state ≈ [96, 32]; one β = 0.5 tick of [0, 200] flips the order).
        ema_masses(&mut smoothed, &[0.0, 200.0], &sizes, 0.5);
        assert!(smoothed[1] > smoothed[0], "good step must re-steer: {smoothed:?}");
        // Non-finite state totals (never produced by this function, but
        // reachable if a caller seeds by hand) fall back to the raw sizes.
        let mut poisoned = vec![f64::NAN, 1.0];
        ema_masses(&mut poisoned, &[f64::NAN, 1.0], &sizes, 0.5);
        assert!(poisoned.iter().all(|m| m.is_finite()), "{poisoned:?}");
        // An unseeded state hit by a degenerate first step seeds from the
        // sizes directly rather than staying empty.
        let mut empty = Vec::new();
        ema_masses(&mut empty, &[f64::NAN, 1.0], &sizes, 0.5);
        assert_eq!(empty, vec![96.0, 32.0]);
    }

    #[test]
    fn pipeline_producer_state_is_sequential() {
        // The producer's own mutable state must evolve in bucket order even
        // though it runs on another thread.
        let mut counter = 0usize;
        let mut seen = Vec::new();
        run_pipelined(
            5,
            move |b| {
                counter += b;
                (b, counter)
            },
            |_, item| seen.push(item),
        );
        assert_eq!(seen, vec![(0, 0), (1, 1), (2, 3), (3, 6), (4, 10)]);
    }
}
