//! Warm-threshold selection (`select = warm:TAU`): cross-step threshold
//! reuse with a fused single-pass compression scan.
//!
//! The source paper's Fig. 2/7 observation — gradient magnitudes are
//! near-Gaussian and their distribution is *stable across adjacent
//! steps* — means step t−1's selection threshold is already an excellent
//! threshold for step t. The cold paths re-derive it from scratch every
//! step (TopK: |u| materialization + quickselect over all d; GaussianK:
//! fit + up to four refinement passes), and the trainer pays *separate*
//! O(d) sweeps for the adaptive-δ feedback histogram and the `mass`
//! apportionment. The warm engine collapses all of that into **one
//! linear scan** per step:
//!
//! ```text
//!            ┌──────────────── cold ────────────────┐
//!            │ seed = Compressor::cold_threshold    │
//!            │ (TopK exact quickselect / GaussianK  │
//!            │  fitted + refined threshold)         │
//!            └──────────────────┬───────────────────┘
//!                               ▼
//!   ┌─────────────────── fused single pass ───────────────────┐
//!   │ for each u_i:   mass += u_i²        (apportionment)     │
//!   │                 span  = max(span, |u_i|)                │
//!   │                 hist[bin(|u_i|)] += 1   (adaptive δ)    │
//!   │                 if |u_i| > thres: hits.push((i, u_i))   │
//!   └──────────────────────────┬──────────────────────────────┘
//!                              ▼
//!          hits ≥ k ──────► O(hits) truncation to exactly k
//!          (warm hit;        (quickselect over the hits only,
//!           never a rescan)   TopK tie-break semantics)
//!          hits < k ──────► cold rescan (full quickselect) and
//!          (miss)            cache refresh
//! ```
//!
//! **State machine.** Each selection domain (the monolithic gradient, or
//! one slot per bucket) owns a [`ThresholdCache`]: `cold` (no pivot) →
//! first call seeds from the operator's own derivation → `warm` (pivot
//! cached). A warm step whose hit count lands in `[k, (1+τ)·k]` counts
//! as a **hit**; hit counts above the band are still repaired by the
//! O(hits) truncation (over-selection never forces a rescan) but count
//! as drift **misses** and refresh the pivot; hit counts below `k`
//! under-select and trigger the only true cold rescan. The cached pivot
//! is maintained at magnitude rank `m = ceil(k·(1+τ/2))` — mid-band, so
//! both band edges have τ/2·k of slack before gradual distribution
//! drift forces a refresh.
//!
//! **Contract.** Warm selection always emits exactly `min(k, d)`
//! elements with TopK's tie-break semantics (strictly-above first, then
//! pivot-equal ties in index order), values unchanged from `u`, indices
//! ascending. It is deterministic and bit-identical across the
//! serial/threads/pool runtimes — the cache lives in per-worker state
//! (`WorkerState`), so the pool's ownership ping-pong carries it across
//! steps with zero steady-state allocations and placement cannot change
//! results. It is **not** bit-identical to `select = exact`: warm is its
//! own trajectory (same k per step, slightly different tie resolution
//! history is avoided — the selected *set* can differ from GaussianK's
//! approximate counts by design).

use super::{Compressor, Workspace};
use crate::schedule::FEEDBACK_BINS;
use crate::stats::Histogram;
use crate::tensor::SparseVec;
use std::cmp::Ordering;

/// Cross-step pivot state for one selection domain (the monolithic
/// gradient or a single bucket).
#[derive(Debug, Default, Clone)]
pub struct ThresholdCache {
    /// Pivot magnitude cached from the previous step (`None` = cold).
    thres: Option<f32>,
}

/// Fused by-products of one completed warm step, published for the
/// trainer to reuse in place of its own O(d) sweeps.
#[derive(Debug, Clone)]
pub struct WarmStats {
    /// |u| histogram over the worker's previous-step span (`None` when
    /// the span was still unknown — first step — or the run doesn't
    /// need feedback). Spans differ per worker; that is fine, the
    /// trainer folds with [`crate::schedule::fold_feedback_histograms`]
    /// which re-bins onto the common span.
    pub histogram: Option<Histogram>,
    /// Per-slot Σ u² of the scanned slice(s), in slot (bucket) order.
    pub masses: Vec<f64>,
}

/// Per-worker warm-selection engine: one [`ThresholdCache`] per slot,
/// the fused-scan accumulators, and the hit/miss telemetry.
#[derive(Debug, Clone)]
pub struct WarmSelector {
    tau: f64,
    caches: Vec<ThresholdCache>,
    /// max |u| observed across all slots of the *previous* step — the
    /// feedback-histogram span for the current step's fused scan.
    span: f64,
    /// Whether the current run's schedule consumes |u| feedback.
    want_hist: bool,
    // Per-step accumulators (reset when slot 0 is scanned).
    step_span: f64,
    produced: usize,
    hist: Option<Histogram>,
    masses: Vec<f64>,
    /// Stats of the most recent completed step.
    stats: Option<WarmStats>,
    /// Warm steps whose hit count landed inside `[k, (1+τ)·k]`.
    pub hits: u64,
    /// Cold seeds, under-selections, and drift refreshes.
    pub misses: u64,
}

fn desc(a: &f32, b: &f32) -> Ordering {
    b.total_cmp(a)
}

impl WarmSelector {
    /// A monolithic (single-slot) selector. τ must already be validated
    /// (`Select::warm`): τ ∈ (0, 1).
    pub fn new(tau: f64) -> WarmSelector {
        WarmSelector {
            tau,
            caches: vec![ThresholdCache::default()],
            span: 0.0,
            want_hist: false,
            step_span: 0.0,
            produced: 0,
            hist: None,
            masses: vec![0.0],
            stats: None,
            hits: 0,
            misses: 0,
        }
    }

    /// Size for a bucketed run: one cache slot per bucket. Buckets are
    /// compressed in ascending index order per worker on every runtime,
    /// so slot 0 opens a step and slot `nb − 1` closes it.
    pub fn init_slots(&mut self, nb: usize) {
        let nb = nb.max(1);
        self.caches = vec![ThresholdCache::default(); nb];
        self.masses = vec![0.0; nb];
        self.produced = 0;
        self.stats = None;
    }

    pub fn slots(&self) -> usize {
        self.caches.len()
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }

    /// Toggle histogram fill in the fused scan (set from the schedule's
    /// `wants_feedback`; binning needs the previous step's span, so the
    /// first step always reports `histogram: None`).
    pub fn set_want_hist(&mut self, want: bool) {
        self.want_hist = want;
    }

    /// Take the fused stats of the most recent *completed* step (all
    /// slots scanned). The trainer substitutes these for its own
    /// feedback/mass sweeps; `None` means "sweep yourself".
    pub fn take_stats(&mut self) -> Option<WarmStats> {
        self.stats.take()
    }

    /// Whether a completed step's fused stats are banked (including a
    /// histogram, when `need_hist` — the first step's scan has no span
    /// to bin against, so its stats carry `histogram: None`).
    pub fn stats_ready(&self, need_hist: bool) -> bool {
        self.stats
            .as_ref()
            .is_some_and(|s| !need_hist || s.histogram.is_some())
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Warm-select `min(k, d)` elements of `u` for `slot`, updating the
    /// fused per-step stats. `comp` supplies the cold seed
    /// ([`Compressor::cold_threshold`]) and, for operators without a
    /// threshold concept, the exact delegation target.
    pub fn compress_step(
        &mut self,
        comp: &mut dyn Compressor,
        slot: usize,
        u: &[f32],
        k: usize,
        ws: &mut Workspace,
    ) -> SparseVec {
        debug_assert!(slot < self.caches.len(), "warm slot out of range");
        if slot == 0 {
            // A new step opens: reset the per-step accumulators.
            self.produced = 0;
            self.step_span = 0.0;
            for m in &mut self.masses {
                *m = 0.0;
            }
            self.hist = if self.want_hist && self.span > 0.0 {
                Some(Histogram::new(0.0, self.span.max(1e-12), FEEDBACK_BINS))
            } else {
                None
            };
        }
        let payload = self.select_slot(comp, slot, u, k, ws);
        self.produced += 1;
        if self.produced == self.caches.len() {
            // Step complete: publish the fused stats, roll the span.
            self.span = self.step_span;
            self.stats = Some(WarmStats {
                histogram: self.hist.take(),
                masses: self.masses.clone(),
            });
        }
        payload
    }

    fn select_slot(
        &mut self,
        comp: &mut dyn Compressor,
        slot: usize,
        u: &[f32],
        k: usize,
        ws: &mut Workspace,
    ) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        // Stats-only / degenerate budgets: the fused pass still runs so
        // the schedule and apportionment see every slot.
        if k == 0 {
            self.scan(slot, u, f32::INFINITY, ws);
            ws.pairs.clear();
            return SparseVec::new(d);
        }
        if k == d {
            self.scan(slot, u, f32::INFINITY, ws);
            ws.pairs.clear();
            return comp.compress_step(u, k, ws);
        }
        let (thres, from_cache) = match self.caches[slot].thres {
            Some(t) => (t, true),
            None => match comp.cold_threshold(u, k, ws) {
                Some(t) if t.is_finite() => (t.max(0.0), false),
                // No threshold concept (RandK/DGC/...) or a broken fit:
                // exact delegation, stats from a hit-free scan.
                _ => {
                    self.scan(slot, u, f32::INFINITY, ws);
                    ws.pairs.clear();
                    return comp.compress_step(u, k, ws);
                }
            },
        };
        self.scan(slot, u, thres, ws);
        let hits = ws.pairs.len();
        let band_hi = (((1.0 + self.tau) * k as f64).floor() as usize).max(k);
        let m = (((k as f64) * (1.0 + 0.5 * self.tau)).ceil() as usize).clamp(k, d);
        if hits >= k {
            // The hits are a superset of the exact top-k: repair
            // over-selection with an O(hits) truncation — never a
            // rescan. In-band counts are warm hits; above-band counts
            // are drift misses that refresh the pivot.
            if from_cache && hits <= band_hi {
                self.hits += 1;
            } else {
                self.misses += 1;
            }
            ws.abs.clear();
            ws.abs.extend(ws.pairs.iter().map(|p| p.1.abs()));
            let pivot = if hits >= m {
                // Enough hits to re-derive the mid-band pivot: the set
                // {|u_i| > thres} is exactly the global top-`hits`, so
                // rank m among hits is the global rank-m magnitude.
                let (_, mth, _) = ws.abs.select_nth_unstable_by(m - 1, desc);
                self.caches[slot].thres = Some(*mth);
                let (_, kth, _) = ws.abs[..m].select_nth_unstable_by(k - 1, desc);
                *kth
            } else {
                // In-band but below the refresh rank: the scan threshold
                // itself is the best pivot we have — keep (or adopt) it.
                if !from_cache {
                    self.caches[slot].thres = Some(thres);
                }
                let (_, kth, _) = ws.abs.select_nth_unstable_by(k - 1, desc);
                *kth
            };
            return take_k_from_hits(pivot, k, d, ws);
        }
        // Under-selection: the cached pivot went stale upward (or the
        // cold seed overshot). The one true cold rescan: full |u|
        // quickselect, exact top-k payload, pivot refreshed at rank m.
        self.misses += 1;
        ws.abs.clear();
        ws.abs.extend(u.iter().map(|v| v.abs()));
        let (_, mth, _) = ws.abs.select_nth_unstable_by(m - 1, desc);
        self.caches[slot].thres = Some(*mth);
        let (_, kth, _) = ws.abs[..m].select_nth_unstable_by(k - 1, desc);
        let pivot = *kth;
        take_k_exact(u, pivot, k, ws)
    }

    /// The fused single pass: partition |u| against `thres` into
    /// `ws.pairs` (index order), accumulate Σ u² into this slot's mass,
    /// track the step's max |u|, and bin |u| into the step histogram
    /// when one is active — one memory sweep feeding selection, the
    /// adaptive-δ schedule, and `mass` apportionment together.
    fn scan(&mut self, slot: usize, u: &[f32], thres: f32, ws: &mut Workspace) {
        ws.pairs.clear();
        let mut mass = 0.0f64;
        let mut span = self.step_span;
        match &mut self.hist {
            Some(h) => {
                let bins = h.counts.len() as f64;
                let hi = h.hi;
                for (i, &v) in u.iter().enumerate() {
                    let a = (v as f64).abs();
                    mass += (v as f64) * (v as f64);
                    span = span.max(a);
                    // Mirrors Histogram::bin_of with lo = 0 (clamped).
                    let b = ((a / hi * bins).floor().max(0.0) as usize)
                        .min(h.counts.len() - 1);
                    h.counts[b] += 1;
                    if v.abs() > thres {
                        ws.pairs.push((i as u32, v));
                    }
                }
                h.total += u.len() as u64;
            }
            None => {
                for (i, &v) in u.iter().enumerate() {
                    let a = (v as f64).abs();
                    mass += (v as f64) * (v as f64);
                    span = span.max(a);
                    if v.abs() > thres {
                        ws.pairs.push((i as u32, v));
                    }
                }
            }
        }
        self.step_span = span;
        self.masses[slot] = mass;
    }
}

/// Emit exactly `k` of the hits staged in `ws.pairs` with TopK's
/// tie-break semantics: everything strictly above `pivot`, then
/// pivot-equal ties in first-index order. The hits are already in
/// ascending index order, so the output is too.
fn take_k_from_hits(pivot: f32, k: usize, d: usize, ws: &mut Workspace) -> SparseVec {
    let mut above = 0usize;
    for &(_, v) in &ws.pairs {
        if v.abs() > pivot {
            above += 1;
        }
    }
    let mut tie_budget = k - above;
    let (mut indices, mut values) = ws.out_buffers(k);
    for &(i, v) in &ws.pairs {
        let a = v.abs();
        if a > pivot {
            indices.push(i);
            values.push(v);
        } else if a == pivot && tie_budget > 0 {
            indices.push(i);
            values.push(v);
            tie_budget -= 1;
        }
    }
    debug_assert_eq!(indices.len(), k);
    SparseVec { d, indices, values }
}

/// The cold-rescan emitter: same tie-break contract as
/// [`take_k_from_hits`] but walking all of `u` (the hit list is too
/// short to cover k).
fn take_k_exact(u: &[f32], pivot: f32, k: usize, ws: &mut Workspace) -> SparseVec {
    let mut above = 0usize;
    for &v in u {
        if v.abs() > pivot {
            above += 1;
        }
    }
    let mut tie_budget = k - above;
    let (mut indices, mut values) = ws.out_buffers(k);
    for (i, &v) in u.iter().enumerate() {
        let a = v.abs();
        if a > pivot {
            indices.push(i as u32);
            values.push(v);
        } else if a == pivot && tie_budget > 0 {
            indices.push(i as u32);
            values.push(v);
            tie_budget -= 1;
        }
    }
    debug_assert_eq!(indices.len(), k);
    SparseVec {
        d,
        indices,
        values,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{GaussianK, TopK};
    use crate::stats::rng::Pcg64;

    fn bell(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        (0..d).map(|_| rng.next_gaussian() as f32 * 0.1).collect()
    }

    fn exact_topk(u: &[f32], k: usize) -> SparseVec {
        let mut ws = Workspace::new();
        TopK::new().compress_step(u, k, &mut ws)
    }

    #[test]
    fn warm_payload_is_exact_topk_set_on_stable_stream() {
        // Stationary magnitude distribution: hit or miss, the payload
        // must equal exact TopK every step (same set, same order, same
        // values), and most steps must be warm hits. The hit count is
        // deterministic (fixed seeds); at τ = 0.5 the band absorbs the
        // √m fluctuation of the hit count around the refresh rank, so
        // the stream is mostly hits (17/20 here; asserted with margin).
        let mut sel = WarmSelector::new(0.5);
        let mut ws = Workspace::new();
        let mut op = TopK::new();
        for step in 0..20 {
            let u = bell(4096, 100 + step);
            let k = 64;
            let warm = sel.compress_step(&mut op, 0, &u, k, &mut ws);
            let exact = exact_topk(&u, k);
            assert_eq!(warm.indices, exact.indices, "step {step}");
            assert_eq!(warm.values, exact.values, "step {step}");
        }
        assert!(
            sel.hits >= 14,
            "stationary stream should be mostly warm hits, got {}/{}",
            sel.hits,
            sel.hits + sel.misses
        );
    }

    #[test]
    fn warm_count_always_exactly_min_k_d() {
        let mut sel = WarmSelector::new(0.5);
        let mut ws = Workspace::new();
        let mut op = GaussianK::new();
        let mut rng = Pcg64::seed(9);
        for step in 0..30 {
            // Magnitude scale drifts hard to force misses and refreshes.
            let scale = (1.0 + (step as f32 * 1.7).sin().abs() * 50.0) as f64;
            let d = 1000 + (step * 37) % 500;
            let u: Vec<f32> = (0..d)
                .map(|_| (rng.next_gaussian() * scale) as f32)
                .collect();
            let k = 1 + (step * 13) % 80;
            let s = sel.compress_step(&mut op, 0, &u, k, &mut ws);
            assert_eq!(s.nnz(), k.min(d), "step {step}");
            // Values must be unchanged coordinates of u.
            for (&i, &v) in s.indices.iter().zip(&s.values) {
                assert_eq!(u[i as usize], v);
            }
            // Indices ascending.
            assert!(s.indices.windows(2).all(|w| w[0] < w[1]));
        }
        assert!(sel.misses > 0, "drifting stream must refresh at least once");
    }

    #[test]
    fn warm_handles_k_zero_and_k_equals_d() {
        let mut sel = WarmSelector::new(0.25);
        let mut ws = Workspace::new();
        let mut op = TopK::new();
        let u = bell(256, 7);
        let s = sel.compress_step(&mut op, 0, &u, 0, &mut ws);
        assert_eq!(s.nnz(), 0);
        let s = sel.compress_step(&mut op, 0, &u, 256, &mut ws);
        assert_eq!(s.nnz(), 256);
        let s = sel.compress_step(&mut op, 0, &u, 10_000, &mut ws);
        assert_eq!(s.nnz(), 256);
    }

    #[test]
    fn warm_ties_resolve_first_index_like_topk() {
        // All-equal magnitudes: warm truncation must pick the first k
        // indices, exactly like TopK's tie contract.
        let mut sel = WarmSelector::new(0.25);
        let mut ws = Workspace::new();
        let mut op = TopK::new();
        let u = vec![0.5f32; 100];
        for _ in 0..3 {
            let s = sel.compress_step(&mut op, 0, &u, 8, &mut ws);
            assert_eq!(s.indices, (0..8).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn fused_stats_match_separate_sweeps() {
        let mut sel = WarmSelector::new(0.25);
        sel.set_want_hist(true);
        let mut ws = Workspace::new();
        let mut op = TopK::new();
        let u0 = bell(2048, 42);
        // First step: span unknown, no histogram yet.
        sel.compress_step(&mut op, 0, &u0, 32, &mut ws);
        let st = sel.take_stats().expect("step completed");
        assert!(st.histogram.is_none());
        let exact_mass: f64 = u0.iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!((st.masses[0] - exact_mass).abs() <= 1e-12 * exact_mass.max(1.0));
        // Second step: histogram over step-1's span. Against a fresh
        // exact feedback_histogram of the same data the counts can shift
        // by the span difference; with identical data they must agree
        // bin-for-bin because the span is identical.
        sel.compress_step(&mut op, 0, &u0, 32, &mut ws);
        let st = sel.take_stats().expect("step completed");
        let h = st.histogram.expect("span known after one step");
        let exact_h = crate::schedule::feedback_histogram(&u0);
        assert_eq!(h.total, exact_h.total);
        assert!((h.hi - exact_h.hi).abs() < 1e-12);
        assert_eq!(h.counts, exact_h.counts);
    }

    #[test]
    fn bucketed_slots_keep_independent_caches() {
        let mut sel = WarmSelector::new(0.5);
        sel.init_slots(3);
        let mut ws = Workspace::new();
        let mut op = TopK::new();
        for step in 0..5 {
            for slot in 0..3 {
                // Per-slot scales differ by 100×: a shared cache would
                // trash the small-scale slots into permanent misses.
                let scale = 10f32.powi(slot as i32);
                let u: Vec<f32> =
                    bell(512, 7 * step + slot as u64).iter().map(|v| v * scale).collect();
                let s = sel.compress_step(&mut op, slot as usize, &u, 16, &mut ws);
                assert_eq!(s.nnz(), 16);
            }
        }
        // Deterministic (fixed seeds): 9 hits / 6 misses at τ = 0.5 and
        // k = 16 — small k means a wide relative hit-count fluctuation,
        // so a majority of hits is the honest bar. A *shared* cache
        // would make the two small-scale slots permanent misses (≤ 5
        // hits possible).
        assert!(
            sel.hits >= 6,
            "independent slots should warm up, got {}/{}",
            sel.hits,
            sel.hits + sel.misses
        );
    }

    #[test]
    fn non_threshold_op_delegates_exactly() {
        use crate::compress::RandK;
        let mut sel = WarmSelector::new(0.25);
        let mut ws = Workspace::new();
        let u = bell(512, 3);
        let mut warm_op = RandK::new(7);
        let s_warm = sel.compress_step(&mut warm_op, 0, &u, 32, &mut ws);
        let mut exact_op = RandK::new(7);
        let mut ws2 = Workspace::new();
        let s_exact = exact_op.compress_step(&u, 32, &mut ws2);
        assert_eq!(s_warm.indices, s_exact.indices);
        assert_eq!(s_warm.values, s_exact.values);
        assert_eq!(sel.hits, 0);
    }

    #[test]
    fn warm_selector_is_deterministic() {
        let run = || {
            let mut sel = WarmSelector::new(0.3);
            sel.set_want_hist(true);
            let mut ws = Workspace::new();
            let mut op = GaussianK::new();
            let mut out = Vec::new();
            for step in 0..10 {
                let u = bell(2000, 31 + step);
                let s = sel.compress_step(&mut op, 0, &u, 50, &mut ws);
                out.push((s.indices.clone(), s.values.clone()));
            }
            (out, sel.hits, sel.misses)
        };
        assert_eq!(run(), run());
    }
}
