//! `Rand_k`: uniformly random k-subset selection (the baseline whose
//! contraction bound E‖u − Rand_k(u)‖² = (1 − k/d)‖u‖² is *exact* — Eq. 4
//! of the paper — and which converges far slower than Top_k in practice,
//! Fig. 1).

use super::{Compressor, Workspace};
use crate::stats::rng::Pcg64;
use crate::tensor::SparseVec;

/// Uniform random-k selection with a deterministic per-instance stream.
/// The per-step k comes from the schedule plan; `k == 0` returns an empty
/// payload without advancing the RNG stream.
pub struct RandK {
    rng: Pcg64,
}

impl RandK {
    pub fn new(seed: u64) -> RandK {
        RandK {
            rng: Pcg64::seed(seed ^ 0x52414e44), // "RAND"
        }
    }
}

impl Compressor for RandK {
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return SparseVec::new(d);
        }
        let mut idx = self.rng.sample_indices(d, k);
        idx.sort_unstable();
        let (mut indices, mut values) = ws.out_buffers(k);
        indices.extend(idx.iter().map(|&i| i as u32));
        values.extend(idx.iter().map(|&i| u[i]));
        SparseVec { d, indices, values }
    }

    fn name(&self) -> &'static str {
        "randk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn exact_k_distinct() {
        let u: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut op = RandK::new(1);
        let s = op.compress_step(&u, 10, &mut Workspace::new());
        assert_eq!(s.nnz(), 10);
        assert!(s.indices.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn deterministic_given_seed() {
        let u: Vec<f32> = (0..50).map(|i| (i as f32).sin()).collect();
        let mut ws = Workspace::new();
        let a = RandK::new(42).compress_step(&u, 5, &mut ws);
        let b = RandK::new(42).compress_step(&u, 5, &mut ws);
        assert_eq!(a, b);
    }

    #[test]
    fn different_calls_differ() {
        let u: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut op = RandK::new(3);
        let mut ws = Workspace::new();
        let a = op.compress_step(&u, 10, &mut ws);
        let b = op.compress_step(&u, 10, &mut ws);
        assert_ne!(a.indices, b.indices, "consecutive draws should differ");
    }

    #[test]
    fn zero_k_leaves_stream_untouched() {
        // A k = 0 step (e.g. a starved bucket) must not perturb the
        // stream the next non-empty step draws from.
        let u = vec![1.0f32; 64];
        let mut ws = Workspace::new();
        let mut with_gap = RandK::new(9);
        assert_eq!(with_gap.compress_step(&u, 0, &mut ws).nnz(), 0);
        let after_gap = with_gap.compress_step(&u, 8, &mut ws);
        let direct = RandK::new(9).compress_step(&u, 8, &mut ws);
        assert_eq!(after_gap.indices, direct.indices);
    }

    /// Eq. 4: E‖u − Rand_k(u)‖² = (1 − k/d)‖u‖² — check the empirical mean
    /// over many draws is close to the exact expectation.
    #[test]
    fn expectation_matches_exact_bound() {
        let mut rng = Pcg64::seed(9);
        let d = 2000;
        let k = 200;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let u_norm = crate::stats::norm2_sq(&u);
        let mut op = RandK::new(5);
        let mut ws = Workspace::new();
        let trials = 300;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let s = op.compress_step(&u, k, &mut ws);
            acc += u_norm - s.norm2_sq(); // residual energy
            ws.recycle(s);
        }
        let mean_ratio = acc / trials as f64 / u_norm;
        let expect = 1.0 - k as f64 / d as f64;
        assert!(
            (mean_ratio - expect).abs() < 0.02,
            "mean ratio {mean_ratio} vs exact {expect}"
        );
    }

    /// Uniformity: every coordinate is selected with probability ≈ k/d.
    #[test]
    fn prop_uniform_coverage() {
        testkit::forall("randk-uniform", |g: &mut Gen| {
            let d = g.usize_in(50, 200);
            let k = g.usize_in(1, d / 2);
            let u = vec![1.0f32; d];
            let mut op = RandK::new(g.rng.next_u64());
            let mut ws = Workspace::new();
            let trials = 400;
            let mut hits = vec![0usize; d];
            for _ in 0..trials {
                let s = op.compress_step(&u, k, &mut ws);
                for &i in &s.indices {
                    hits[i as usize] += 1;
                }
                ws.recycle(s);
            }
            let expect = trials as f64 * k as f64 / d as f64;
            // 6-sigma binomial bound.
            let sigma = (expect * (1.0 - k as f64 / d as f64)).sqrt();
            for (i, &h) in hits.iter().enumerate() {
                if (h as f64 - expect).abs() > 6.0 * sigma + 1.0 {
                    return Err(format!("coord {i}: {h} hits, expect {expect:.1}±{sigma:.1}"));
                }
            }
            Ok(())
        });
    }
}
