//! `Rand_k`: uniformly random k-subset selection (the baseline whose
//! contraction bound E‖u − Rand_k(u)‖² = (1 − k/d)‖u‖² is *exact* — Eq. 4
//! of the paper — and which converges far slower than Top_k in practice,
//! Fig. 1).

use super::Compressor;
use crate::stats::rng::Pcg64;
use crate::tensor::SparseVec;

/// Uniform random-k selection with a deterministic per-instance stream.
pub struct RandK {
    k: usize,
    rng: Pcg64,
}

impl RandK {
    pub fn new(k: usize, seed: u64) -> RandK {
        assert!(k > 0, "RandK requires k >= 1");
        RandK {
            k,
            rng: Pcg64::seed(seed ^ 0x52414e44), // "RAND"
        }
    }
}

impl Compressor for RandK {
    fn compress(&mut self, u: &[f32]) -> SparseVec {
        let d = u.len();
        let k = self.k.min(d);
        let mut idx = self.rng.sample_indices(d, k);
        idx.sort_unstable();
        SparseVec {
            d,
            values: idx.iter().map(|&i| u[i]).collect(),
            indices: idx.into_iter().map(|i| i as u32).collect(),
        }
    }

    fn name(&self) -> &'static str {
        "randk"
    }

    fn target_k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn exact_k_distinct() {
        let u: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut op = RandK::new(10, 1);
        let s = op.compress(&u);
        assert_eq!(s.nnz(), 10);
        assert!(s.indices.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn deterministic_given_seed() {
        let u: Vec<f32> = (0..50).map(|i| (i as f32).sin()).collect();
        let a = RandK::new(5, 42).compress(&u);
        let b = RandK::new(5, 42).compress(&u);
        assert_eq!(a, b);
    }

    #[test]
    fn different_calls_differ() {
        let u: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut op = RandK::new(10, 3);
        let a = op.compress(&u);
        let b = op.compress(&u);
        assert_ne!(a.indices, b.indices, "consecutive draws should differ");
    }

    /// Eq. 4: E‖u − Rand_k(u)‖² = (1 − k/d)‖u‖² — check the empirical mean
    /// over many draws is close to the exact expectation.
    #[test]
    fn expectation_matches_exact_bound() {
        let mut rng = Pcg64::seed(9);
        let d = 2000;
        let k = 200;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let u_norm = crate::stats::norm2_sq(&u);
        let mut op = RandK::new(k, 5);
        let trials = 300;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let s = op.compress(&u);
            acc += u_norm - s.norm2_sq(); // residual energy
        }
        let mean_ratio = acc / trials as f64 / u_norm;
        let expect = 1.0 - k as f64 / d as f64;
        assert!(
            (mean_ratio - expect).abs() < 0.02,
            "mean ratio {mean_ratio} vs exact {expect}"
        );
    }

    /// Uniformity: every coordinate is selected with probability ≈ k/d.
    #[test]
    fn prop_uniform_coverage() {
        testkit::forall("randk-uniform", |g: &mut Gen| {
            let d = g.usize_in(50, 200);
            let k = g.usize_in(1, d / 2);
            let u = vec![1.0f32; d];
            let mut op = RandK::new(k, g.rng.next_u64());
            let trials = 400;
            let mut hits = vec![0usize; d];
            for _ in 0..trials {
                for &i in &op.compress(&u).indices {
                    hits[i as usize] += 1;
                }
            }
            let expect = trials as f64 * k as f64 / d as f64;
            // 6-sigma binomial bound.
            let sigma = (expect * (1.0 - k as f64 / d as f64)).sqrt();
            for (i, &h) in hits.iter().enumerate() {
                if (h as f64 - expect).abs() > 6.0 * sigma + 1.0 {
                    return Err(format!("coord {i}: {h} hits, expect {expect:.1}±{sigma:.1}"));
                }
            }
            Ok(())
        });
    }
}
