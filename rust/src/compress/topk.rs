//! Exact `Top_k`: select the k largest-magnitude coordinates.
//!
//! Algorithm: quickselect (`select_nth_unstable_by`) on a scratch copy of
//! |u| to find the k-th largest magnitude in expected O(d), then one pass
//! collecting elements above the pivot with exact tie-breaking so the
//! output has *exactly* k non-zeros (matching `tensor.topk()` semantics in
//! the paper's PyTorch baseline).
//!
//! This is deliberately the strongest CPU implementation we could write —
//! Fig. 4's comparison is only meaningful if the exact-selection baseline
//! is not a strawman. See EXPERIMENTS.md §Perf for the heap-based variant
//! it replaced.

use super::Compressor;
use crate::tensor::SparseVec;

/// Exact top-k by absolute value.
pub struct TopK {
    k: usize,
    /// Reusable scratch buffer (avoids the O(d) allocation per step).
    scratch: Vec<f32>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        assert!(k > 0, "TopK requires k >= 1");
        TopK {
            k,
            scratch: Vec::new(),
        }
    }

    /// The k-th largest |value| (the exact selection threshold). Exposed
    /// for the analysis harnesses (Fig. 5 uses it to compute exact bounds).
    pub fn exact_threshold(&mut self, u: &[f32]) -> f32 {
        let k = self.k.min(u.len());
        if k == 0 {
            return f32::INFINITY;
        }
        self.scratch.clear();
        self.scratch.extend(u.iter().map(|v| v.abs()));
        let idx = k - 1;
        let (_, kth, _) = self
            .scratch
            .select_nth_unstable_by(idx, |a, b| b.total_cmp(a));
        *kth
    }
}

impl Compressor for TopK {
    fn compress(&mut self, u: &[f32]) -> SparseVec {
        let d = u.len();
        let k = self.k.min(d);
        if k == d {
            return SparseVec {
                d,
                indices: (0..d as u32).collect(),
                values: u.to_vec(),
            };
        }
        let pivot = self.exact_threshold(u);

        // Collect strictly-above-pivot, then fill remaining slots with
        // pivot-equal elements (first-index tie-break, as PyTorch does).
        let mut indices = Vec::with_capacity(k);
        let mut values = Vec::with_capacity(k);
        let mut ties: Vec<u32> = Vec::new();
        for (i, &v) in u.iter().enumerate() {
            let a = v.abs();
            if a > pivot {
                indices.push(i as u32);
                values.push(v);
            } else if a == pivot {
                ties.push(i as u32);
            }
        }
        let missing = k - indices.len();
        for &i in ties.iter().take(missing) {
            indices.push(i);
            values.push(u[i as usize]);
        }
        let mut pairs: Vec<(u32, f32)> = indices.into_iter().zip(values).collect();
        pairs.sort_unstable_by_key(|p| p.0);
        SparseVec {
            d,
            indices: pairs.iter().map(|p| p.0).collect(),
            values: pairs.iter().map(|p| p.1).collect(),
        }
    }

    fn name(&self) -> &'static str {
        "topk"
    }

    fn target_k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn selects_largest_magnitudes() {
        let u = vec![0.1f32, -5.0, 2.0, 0.0, -3.0, 4.0];
        let s = TopK::new(3).compress(&u);
        assert_eq!(s.indices, vec![1, 4, 5]);
        assert_eq!(s.values, vec![-5.0, -3.0, 4.0]);
    }

    #[test]
    fn exact_k_with_ties() {
        let u = vec![1.0f32, -1.0, 1.0, 1.0, -1.0];
        for k in 1..=5 {
            let s = TopK::new(k).compress(&u);
            assert_eq!(s.nnz(), k, "k={k}");
        }
    }

    #[test]
    fn k_ge_d_keeps_all() {
        let u = vec![1.0f32, 2.0];
        let s = TopK::new(10).compress(&u);
        assert_eq!(s.to_dense(), u);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let u = vec![3.0f32, -1.0, 4.0, -1.5, 5.0];
        let mut t = TopK::new(2);
        assert_eq!(t.exact_threshold(&u), 4.0);
        let mut t5 = TopK::new(5);
        assert_eq!(t5.exact_threshold(&u), 1.0);
    }

    /// Top_k optimality: no unselected |v| exceeds the smallest selected.
    #[test]
    fn prop_optimality() {
        testkit::forall("topk-optimality", |g: &mut Gen| {
            let d = g.usize_in(8, 4096);
            let k = g.usize_in(1, d);
            let u = g.mixed_vec(d);
            let s = TopK::new(k).compress(&u);
            if s.nnz() != k.min(d) {
                return Err(format!("nnz {} != k {}", s.nnz(), k.min(d)));
            }
            let min_sel = s.values.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
            let sel: std::collections::HashSet<u32> = s.indices.iter().copied().collect();
            for (i, &v) in u.iter().enumerate() {
                if !sel.contains(&(i as u32)) && v.abs() > min_sel {
                    return Err(format!("unselected |u[{i}]|={} > min selected {min_sel}", v.abs()));
                }
            }
            Ok(())
        });
    }

    /// The theoretical identity: residual² = Σ_{i>k} π(i)² ‖u‖∞² (Eq. 5) —
    /// cross-checked by sorting.
    #[test]
    fn prop_matches_sorted_tail() {
        testkit::forall("topk-tail-energy", |g: &mut Gen| {
            let d = g.usize_in(8, 1024);
            let k = g.usize_in(1, d);
            let u = g.gaussian_vec(d, 0.0, 1.0);
            let s = TopK::new(k).compress(&u);
            let dense = s.to_dense();
            let resid_sq: f64 = u
                .iter()
                .zip(&dense)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let mut mags: Vec<f64> = u.iter().map(|v| (v.abs() as f64).powi(2)).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let tail: f64 = mags[k.min(d)..].iter().sum();
            if (resid_sq - tail).abs() > 1e-6 * tail.max(1e-12) + 1e-9 {
                return Err(format!("residual {resid_sq} vs sorted tail {tail}"));
            }
            Ok(())
        });
    }

    #[test]
    fn large_vector_smoke() {
        let mut rng = Pcg64::seed(2);
        let u: Vec<f32> = (0..1_000_000).map(|_| rng.next_gaussian() as f32).collect();
        let k = 1000;
        let s = TopK::new(k).compress(&u);
        assert_eq!(s.nnz(), k);
    }
}
