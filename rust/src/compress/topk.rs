//! Exact `Top_k`: select the k largest-magnitude coordinates.
//!
//! Algorithm: quickselect (`select_nth_unstable_by`) on a workspace copy
//! of |u| to find the k-th largest magnitude in expected O(d), then one
//! pass collecting elements above the pivot with exact tie-breaking so the
//! output has *exactly* k non-zeros (matching `tensor.topk()` semantics in
//! the paper's PyTorch baseline).
//!
//! This is deliberately the strongest CPU implementation we could write —
//! Fig. 4's comparison is only meaningful if the exact-selection baseline
//! is not a strawman. See EXPERIMENTS.md §Perf for the heap-based variant
//! it replaced. All scratch (the |u| copy, tie and pair staging) comes
//! from the caller's [`Workspace`], so steady-state calls are
//! allocation-free at any per-step k.

use super::{Compressor, Workspace};
use crate::tensor::SparseVec;

/// Exact top-k by absolute value (stateless — k arrives per step).
#[derive(Debug, Default)]
pub struct TopK;

impl TopK {
    pub fn new() -> TopK {
        TopK
    }

    /// The k-th largest |value| (the exact selection threshold). Exposed
    /// for the analysis harnesses (Fig. 5 uses it to compute exact bounds).
    pub fn exact_threshold(&self, u: &[f32], k: usize, ws: &mut Workspace) -> f32 {
        let k = k.min(u.len());
        if k == 0 {
            return f32::INFINITY;
        }
        ws.abs.clear();
        ws.abs.extend(u.iter().map(|v| v.abs()));
        let idx = k - 1;
        let (_, kth, _) = ws.abs.select_nth_unstable_by(idx, |a, b| b.total_cmp(a));
        *kth
    }
}

impl Compressor for TopK {
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return SparseVec::new(d);
        }
        if k == d {
            let (mut indices, mut values) = ws.out_buffers(d);
            indices.extend_from_slice(ws.identity(d));
            values.extend_from_slice(u);
            return SparseVec { d, indices, values };
        }
        let pivot = self.exact_threshold(u, k, ws);

        // Collect strictly-above-pivot, then fill remaining slots with
        // pivot-equal elements (first-index tie-break, as PyTorch does).
        let (mut indices, mut values) = ws.out_buffers(k);
        ws.ties.clear();
        for (i, &v) in u.iter().enumerate() {
            let a = v.abs();
            if a > pivot {
                indices.push(i as u32);
                values.push(v);
            } else if a == pivot {
                ws.ties.push(i as u32);
            }
        }
        let missing = k - indices.len();
        for &i in ws.ties.iter().take(missing) {
            indices.push(i);
            values.push(u[i as usize]);
        }
        ws.pairs.clear();
        ws.pairs.extend(indices.iter().copied().zip(values.iter().copied()));
        ws.pairs.sort_unstable_by_key(|p| p.0);
        indices.clear();
        values.clear();
        indices.extend(ws.pairs.iter().map(|p| p.0));
        values.extend(ws.pairs.iter().map(|p| p.1));
        SparseVec { d, indices, values }
    }

    fn cold_threshold(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> Option<f32> {
        Some(self.exact_threshold(u, k, ws))
    }

    fn name(&self) -> &'static str {
        "topk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    fn topk(u: &[f32], k: usize) -> SparseVec {
        TopK::new().compress_step(u, k, &mut Workspace::new())
    }

    #[test]
    fn selects_largest_magnitudes() {
        let u = vec![0.1f32, -5.0, 2.0, 0.0, -3.0, 4.0];
        let s = topk(&u, 3);
        assert_eq!(s.indices, vec![1, 4, 5]);
        assert_eq!(s.values, vec![-5.0, -3.0, 4.0]);
    }

    #[test]
    fn exact_k_with_ties() {
        let u = vec![1.0f32, -1.0, 1.0, 1.0, -1.0];
        for k in 1..=5 {
            let s = topk(&u, k);
            assert_eq!(s.nnz(), k, "k={k}");
        }
    }

    #[test]
    fn k_ge_d_keeps_all() {
        let u = vec![1.0f32, 2.0];
        let s = topk(&u, 10);
        assert_eq!(s.to_dense(), u);
    }

    #[test]
    fn varying_k_on_shared_workspace() {
        // The per-step k can change between calls with no stale state.
        let u = vec![0.1f32, -5.0, 2.0, 0.0, -3.0, 4.0];
        let mut op = TopK::new();
        let mut ws = Workspace::new();
        let a = op.compress_step(&u, 1, &mut ws);
        assert_eq!(a.indices, vec![1]);
        ws.recycle(a);
        let b = op.compress_step(&u, 3, &mut ws);
        assert_eq!(b.indices, vec![1, 4, 5]);
        ws.recycle(b);
        let c = op.compress_step(&u, 2, &mut ws);
        assert_eq!(c.indices, vec![1, 5]);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let u = vec![3.0f32, -1.0, 4.0, -1.5, 5.0];
        let mut ws = Workspace::new();
        let t = TopK::new();
        assert_eq!(t.exact_threshold(&u, 2, &mut ws), 4.0);
        assert_eq!(t.exact_threshold(&u, 5, &mut ws), 1.0);
    }

    /// Top_k optimality: no unselected |v| exceeds the smallest selected.
    #[test]
    fn prop_optimality() {
        testkit::forall("topk-optimality", |g: &mut Gen| {
            let d = g.usize_in(8, 4096);
            let k = g.usize_in(1, d);
            let u = g.mixed_vec(d);
            let s = topk(&u, k);
            if s.nnz() != k.min(d) {
                return Err(format!("nnz {} != k {}", s.nnz(), k.min(d)));
            }
            let min_sel = s.values.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
            let sel: std::collections::HashSet<u32> = s.indices.iter().copied().collect();
            for (i, &v) in u.iter().enumerate() {
                if !sel.contains(&(i as u32)) && v.abs() > min_sel {
                    return Err(format!("unselected |u[{i}]|={} > min selected {min_sel}", v.abs()));
                }
            }
            Ok(())
        });
    }

    /// The theoretical identity: residual² = Σ_{i>k} π(i)² ‖u‖∞² (Eq. 5) —
    /// cross-checked by sorting.
    #[test]
    fn prop_matches_sorted_tail() {
        testkit::forall("topk-tail-energy", |g: &mut Gen| {
            let d = g.usize_in(8, 1024);
            let k = g.usize_in(1, d);
            let u = g.gaussian_vec(d, 0.0, 1.0);
            let s = topk(&u, k);
            let dense = s.to_dense();
            let resid_sq: f64 = u
                .iter()
                .zip(&dense)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let mut mags: Vec<f64> = u.iter().map(|v| (v.abs() as f64).powi(2)).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let tail: f64 = mags[k.min(d)..].iter().sum();
            if (resid_sq - tail).abs() > 1e-6 * tail.max(1e-12) + 1e-9 {
                return Err(format!("residual {resid_sq} vs sorted tail {tail}"));
            }
            Ok(())
        });
    }

    #[test]
    fn large_vector_smoke() {
        let mut rng = Pcg64::seed(2);
        let u: Vec<f32> = (0..1_000_000).map(|_| rng.next_gaussian() as f32).collect();
        let k = 1000;
        let s = topk(&u, k);
        assert_eq!(s.nnz(), k);
    }
}
