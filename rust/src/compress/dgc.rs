//! `DGC_k`: Deep Gradient Compression's hierarchical-sampling approximate
//! top-k (Lin et al. 2018), the paper's main approximate-selection
//! baseline (§3.3, Fig. 4).
//!
//! Algorithm (as described in DGC and the paper): sample a fraction
//! (0.1%–1%, we default to 1% as the paper's experiments do) of the
//! gradient, run exact top-k on the *sample* to estimate the threshold,
//! then gather all elements above it; if the gather over-selects, run a
//! second exact top-k on the (small) candidate set — hence "invoke top-k
//! selection twice on subsets of the original vector".

use super::{select_above, Compressor, Workspace};
use crate::stats::rng::Pcg64;
use crate::tensor::SparseVec;

/// DGC hierarchical sampling selector (k arrives per step; `k == 0`
/// returns an empty payload without advancing the sampling stream).
pub struct DgcK {
    /// Sampling fraction (paper uses 1%).
    pub sample_ratio: f64,
    rng: Pcg64,
}

impl DgcK {
    pub fn new(sample_ratio: f64, seed: u64) -> DgcK {
        assert!((0.0..=1.0).contains(&sample_ratio) && sample_ratio > 0.0);
        DgcK {
            sample_ratio,
            rng: Pcg64::seed(seed ^ 0x44474353), // "DGCS"
        }
    }

    /// Estimate the top-k threshold from a uniform sample (stage 1).
    fn sampled_threshold(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> f32 {
        let d = u.len();
        let s = ((d as f64 * self.sample_ratio).ceil() as usize).clamp(1, d);
        // Sample-k proportional to the global k.
        let sample_k = ((k as f64 * s as f64 / d as f64).ceil() as usize).clamp(1, s);
        ws.abs.clear();
        for _ in 0..s {
            let i = self.rng.next_below(d as u64) as usize;
            ws.abs.push(u[i].abs());
        }
        let idx = sample_k - 1;
        let (_, kth, _) = ws.abs.select_nth_unstable_by(idx, |a, b| b.total_cmp(a));
        *kth
    }
}

impl Compressor for DgcK {
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return SparseVec::new(d);
        }
        if k == d {
            return super::Dense.compress_step(u, k, ws);
        }
        let thres = self.sampled_threshold(u, k, ws);
        // Stage 2: gather candidates above the sampled threshold.
        let cand = select_above(u, thres, ws);
        if cand.nnz() <= k {
            // Under-selection: accept (DGC communicates what it found; the
            // residual keeps the rest). Guard the pathological empty case.
            if cand.nnz() == 0 {
                ws.recycle(cand);
                return super::TopK::new().compress_step(u, k, ws);
            }
            return cand;
        }
        // Over-selection: exact top-k on the (small) candidate subset.
        ws.pairs.clear();
        ws.pairs.extend(cand.indices.iter().copied().zip(cand.values.iter().copied()));
        ws.recycle(cand);
        let idx = k - 1;
        ws.pairs.select_nth_unstable_by(idx, |a, b| b.1.abs().total_cmp(&a.1.abs()));
        ws.pairs.truncate(k);
        ws.pairs.sort_unstable_by_key(|p| p.0);
        let (mut indices, mut values) = ws.out_buffers(k);
        indices.extend(ws.pairs.iter().map(|p| p.0));
        values.extend(ws.pairs.iter().map(|p| p.1));
        SparseVec { d, indices, values }
    }

    fn name(&self) -> &'static str {
        "dgc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn never_exceeds_k() {
        let mut rng = Pcg64::seed(20);
        let u: Vec<f32> = (0..50_000).map(|_| rng.next_gaussian() as f32).collect();
        let k = 50;
        let mut op = DgcK::new(0.01, 1);
        let mut ws = Workspace::new();
        for _ in 0..10 {
            let s = op.compress_step(&u, k, &mut ws);
            assert!(s.nnz() <= k, "nnz {} > k {k}", s.nnz());
            assert!(s.nnz() > 0);
            ws.recycle(s);
        }
    }

    #[test]
    fn approximates_exact_topk_energy() {
        // The energy captured by DGC_k should be close to exact Top_k's
        // (that's the whole point of hierarchical sampling).
        let mut rng = Pcg64::seed(21);
        let u: Vec<f32> = (0..100_000).map(|_| rng.next_gaussian() as f32).collect();
        let k = 100;
        let mut ws = Workspace::new();
        let exact = super::super::TopK::new().compress_step(&u, k, &mut ws).norm2_sq();
        let mut op = DgcK::new(0.01, 2);
        let mut acc = 0.0;
        let trials = 50;
        for _ in 0..trials {
            let s = op.compress_step(&u, k, &mut ws);
            acc += s.norm2_sq();
            ws.recycle(s);
        }
        let mean = acc / trials as f64;
        // The sampled threshold is noisy (sample-k is tiny at k = 0.001·d),
        // so DGC under-selects on some draws; half the exact energy on
        // average is the realistic bar (and error feedback recovers the
        // rest across steps).
        assert!(
            mean > 0.5 * exact,
            "DGC captured energy {mean} vs exact {exact}"
        );
    }

    #[test]
    fn handles_spiky_vectors() {
        // Nearly-all-zero vector: sampled threshold likely 0; candidates =
        // the spikes; must not panic and must keep ≤ k.
        let mut u = vec![0.0f32; 10_000];
        u[3] = 100.0;
        u[77] = -50.0;
        let mut op = DgcK::new(0.01, 3);
        let s = op.compress_step(&u, 10, &mut Workspace::new());
        assert!(s.nnz() <= 10);
        assert!(s.indices.contains(&3) || s.indices.contains(&77) || s.nnz() > 0);
    }

    #[test]
    fn prop_bounded_and_valid() {
        testkit::forall("dgc-bounded", |g: &mut Gen| {
            let d = g.usize_in(100, 8192);
            let k = g.usize_in(1, d / 4 + 1);
            let u = g.mixed_vec(d);
            let mut op = DgcK::new(0.01, g.rng.next_u64());
            let s = op.compress_step(&u, k, &mut Workspace::new());
            if s.nnz() > k.max(1) {
                return Err(format!("nnz {} > k {k}", s.nnz()));
            }
            if s.indices.windows(2).any(|w| w[0] >= w[1]) {
                return Err("indices not sorted-unique".into());
            }
            Ok(())
        });
    }
}
