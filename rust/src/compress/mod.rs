//! The sparsification operator zoo (the paper's §3.3 and §4.3):
//!
//! * [`TopK`] — exact top-k selection by |value| (the `Top_k` operator).
//! * [`RandK`] — uniform random-k (`Rand_k`).
//! * [`DgcK`] — DGC's hierarchical-sampling approximate top-k (Lin et al.
//!   2018), the paper's main approximate baseline.
//! * [`TrimmedK`] — RedSync's max/mean-ratio threshold search (Fang et al.
//!   2019), which may select far more than k elements.
//! * [`GaussianK`] — the paper's contribution (Algorithm 1): Gaussian
//!   percent-point-function threshold estimation with a bounded ±50%
//!   refinement loop.
//!
//! All operators implement [`Compressor`]: they take the error-compensated
//! accumulation `u = g + ε` and return a [`SparseVec`] whose kept values
//! are *unchanged* coordinates of `u` (a defining invariant, tested by the
//! property suite).

mod dgc;
mod gaussian;
mod randk;
mod topk;
mod trimmed;

pub use dgc::DgcK;
pub use gaussian::{GaussianK, GaussianKConfig};
pub use randk::RandK;
pub use topk::TopK;
pub use trimmed::TrimmedK;

use crate::tensor::SparseVec;

/// A gradient sparsifier. `compress` must return coordinates of `u`
/// unchanged; implementations aim for ~`target_k` non-zeros (exact for
/// [`TopK`]/[`RandK`], approximate for the threshold-based operators).
pub trait Compressor: Send {
    /// Sparsify `u` (the error-compensated gradient accumulation).
    fn compress(&mut self, u: &[f32]) -> SparseVec;

    /// Operator name for reports (matches the paper's terminology).
    fn name(&self) -> &'static str;

    /// The configured k.
    fn target_k(&self) -> usize;
}

/// Identity "compressor" for Dense-SGD: keeps everything. Exists so the
/// trainer can treat Dense/TopK/... uniformly; the collectives layer
/// routes Dense through ring-allreduce rather than allgather.
pub struct Dense;

impl Compressor for Dense {
    fn compress(&mut self, u: &[f32]) -> SparseVec {
        SparseVec {
            d: u.len(),
            indices: (0..u.len() as u32).collect(),
            values: u.to_vec(),
        }
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn target_k(&self) -> usize {
        usize::MAX
    }
}

/// Operator selector used by configs / CLIs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Dense,
    TopK,
    RandK,
    Dgc,
    Trimmed,
    GaussianK,
}

impl OpKind {
    pub fn parse(s: &str) -> anyhow::Result<OpKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => OpKind::Dense,
            "topk" | "top-k" | "top_k" => OpKind::TopK,
            "randk" | "rand-k" | "rand_k" => OpKind::RandK,
            "dgc" | "dgck" | "dgc_k" => OpKind::Dgc,
            "trimmed" | "trimmedk" | "redsync" => OpKind::Trimmed,
            "gaussiank" | "gaussian-k" | "gaussian_k" | "gaussian" => OpKind::GaussianK,
            other => anyhow::bail!("unknown operator '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Dense => "dense",
            OpKind::TopK => "topk",
            OpKind::RandK => "randk",
            OpKind::Dgc => "dgc",
            OpKind::Trimmed => "trimmed",
            OpKind::GaussianK => "gaussiank",
        }
    }

    /// Instantiate an operator for dimension `d` with `k` targets and a
    /// deterministic seed (used by the stochastic operators).
    pub fn build(&self, k: usize, seed: u64) -> Box<dyn Compressor> {
        match self {
            OpKind::Dense => Box::new(Dense),
            OpKind::TopK => Box::new(TopK::new(k)),
            OpKind::RandK => Box::new(RandK::new(k, seed)),
            OpKind::Dgc => Box::new(DgcK::new(k, 0.01, seed)),
            OpKind::Trimmed => Box::new(TrimmedK::new(k)),
            OpKind::GaussianK => Box::new(GaussianK::new(k)),
        }
    }

    pub fn all() -> &'static [OpKind] {
        &[
            OpKind::Dense,
            OpKind::TopK,
            OpKind::RandK,
            OpKind::Dgc,
            OpKind::Trimmed,
            OpKind::GaussianK,
        ]
    }
}

/// Shared helper: gather all elements with |u[i]| > thres into a sparse
/// vector (single pass; the L3 twin of the Pallas mask kernel's pass 2).
/// `size_hint` pre-sizes the output (the Gaussian_k refinement loop knows
/// the count before selecting — EXPERIMENTS.md §Perf).
pub(crate) fn select_above_hint(u: &[f32], thres: f32, size_hint: usize) -> SparseVec {
    let cap = size_hint.min(u.len());
    let mut indices = Vec::with_capacity(cap);
    let mut values = Vec::with_capacity(cap);
    // Skip-fast: scan 32-wide blocks with two independent vectorizable
    // max-|v| chains and only fall into the scalar gather when the block
    // contains a hit. At k/d ≈ 0.1% the scalar path touches ~3% of blocks,
    // so the sweep approaches pure-load bandwidth (EXPERIMENTS.md §Perf).
    let blocks = u.chunks_exact(32);
    let rem_start = u.len() - blocks.remainder().len();
    for (b, block) in blocks.enumerate() {
        let (mut m0, mut m1) = (0.0f32, 0.0f32);
        for j in 0..16 {
            m0 = m0.max(block[j].abs());
            m1 = m1.max(block[16 + j].abs());
        }
        if m0.max(m1) > thres {
            let base = b * 32;
            for (j, &v) in block.iter().enumerate() {
                if v.abs() > thres {
                    indices.push((base + j) as u32);
                    values.push(v);
                }
            }
        }
    }
    for (j, &v) in u[rem_start..].iter().enumerate() {
        if v.abs() > thres {
            indices.push((rem_start + j) as u32);
            values.push(v);
        }
    }
    SparseVec {
        d: u.len(),
        indices,
        values,
    }
}

pub(crate) fn select_above(u: &[f32], thres: f32) -> SparseVec {
    select_above_hint(u, thres, 16)
}

/// Shared helper: count elements with |u[i]| > thres (pass-only, no
/// allocation — the refinement loop of Gaussian_k uses this). Chunked
/// u32 accumulation so the compare+add vectorizes (≈4× over the naive
/// usize-sum version; EXPERIMENTS.md §Perf).
pub(crate) fn count_above(u: &[f32], thres: f32) -> usize {
    let mut total = 0usize;
    // u32 lanes can't overflow within a 1M-element chunk.
    for chunk in u.chunks(1 << 20) {
        let mut acc = [0u32; 8];
        let lanes = chunk.chunks_exact(8);
        let rem = lanes.remainder();
        for l in lanes {
            for j in 0..8 {
                acc[j] += (l[j].abs() > thres) as u32;
            }
        }
        total += acc.iter().sum::<u32>() as usize
            + rem.iter().filter(|v| v.abs() > thres).count();
    }
    total
}

/// Strided count estimate: counts every `stride`-th element and scales.
/// The Gaussian_k refinement only needs the count to ~±15% (its acceptance
/// band is [2k/3, 4k/3]), so at large d a 1/stride sample gives the same
/// refinement decisions at 1/stride of the memory traffic.
pub(crate) fn count_above_strided(u: &[f32], thres: f32, stride: usize) -> usize {
    if stride <= 1 {
        return count_above(u, thres);
    }
    let mut c = 0usize;
    let mut i = 0;
    while i < u.len() {
        c += (u[i].abs() > thres) as usize;
        i += stride;
    }
    c * stride
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    fn ops_under_test(k: usize) -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(TopK::new(k)),
            Box::new(RandK::new(k, 7)),
            Box::new(DgcK::new(k, 0.01, 7)),
            Box::new(TrimmedK::new(k)),
            Box::new(GaussianK::new(k)),
        ]
    }

    #[test]
    fn opkind_parse_roundtrip() {
        for op in OpKind::all() {
            assert_eq!(OpKind::parse(op.name()).unwrap(), *op);
        }
        assert!(OpKind::parse("nope").is_err());
    }

    #[test]
    fn dense_keeps_everything() {
        let u = vec![1.0f32, -2.0, 0.0, 3.0];
        let s = Dense.compress(&u);
        assert_eq!(s.to_dense(), u);
    }

    #[test]
    fn select_and_count_agree() {
        let mut rng = Pcg64::seed(1);
        let u: Vec<f32> = (0..10_000).map(|_| rng.next_gaussian() as f32).collect();
        for &t in &[0.0f32, 0.5, 1.0, 2.5, 10.0] {
            let s = select_above(&u, t);
            assert_eq!(s.nnz(), count_above(&u, t));
            assert!(s.values.iter().all(|v| v.abs() > t));
        }
    }

    /// Invariant: kept values are unchanged coordinates of u, at their
    /// original indices, with no duplicates (all operators).
    #[test]
    fn prop_values_unchanged() {
        testkit::forall("values-unchanged", |g: &mut Gen| {
            let d = g.usize_in(16, 4096);
            let k = g.usize_in(1, d);
            let u = g.mixed_vec(d);
            for op in ops_under_test(k).iter_mut() {
                let s = op.compress(&u);
                let mut seen = std::collections::HashSet::new();
                for (&i, &v) in s.indices.iter().zip(&s.values) {
                    if i as usize >= d {
                        return Err(format!("{}: index {i} out of range", op.name()));
                    }
                    if !seen.insert(i) {
                        return Err(format!("{}: duplicate index {i}", op.name()));
                    }
                    if u[i as usize].to_bits() != v.to_bits() {
                        return Err(format!(
                            "{}: value changed at {i}: {} -> {v}",
                            op.name(),
                            u[i as usize]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Invariant: residual + compressed == u exactly (error-feedback
    /// decomposition, Eq. 2 of the paper).
    #[test]
    fn prop_exact_decomposition() {
        testkit::forall("exact-decomposition", |g: &mut Gen| {
            let d = g.usize_in(16, 2048);
            let k = g.usize_in(1, d / 2 + 1);
            let mu = g.f32_in(-1.0, 1.0);
            let sigma = g.f32_in(0.01, 2.0);
            let u = g.gaussian_vec(d, mu, sigma);
            for op in ops_under_test(k).iter_mut() {
                let s = op.compress(&u);
                let dense = s.to_dense();
                let resid: Vec<f32> = u.iter().zip(&dense).map(|(a, b)| a - b).collect();
                let recon: Vec<f32> = resid.iter().zip(&dense).map(|(a, b)| a + b).collect();
                testkit::assert_allclose(&recon, &u, 0.0, 0.0)
                    .map_err(|e| format!("{}: {e}", op.name()))?;
            }
            Ok(())
        });
    }

    /// Contraction property (3): ‖u − C(u)‖² ≤ ‖u‖² for every operator
    /// (trivially true since values are kept unchanged, but guards against
    /// sign/scale bugs).
    #[test]
    fn prop_contraction() {
        testkit::forall("contraction", |g: &mut Gen| {
            let d = g.usize_in(16, 2048);
            let k = g.usize_in(1, d);
            let u = g.mixed_vec(d);
            let u_norm = crate::stats::norm2_sq(&u);
            for op in ops_under_test(k).iter_mut() {
                let s = op.compress(&u);
                let dense = s.to_dense();
                let resid: Vec<f32> = u.iter().zip(&dense).map(|(a, b)| a - b).collect();
                let r = crate::stats::norm2_sq(&resid);
                if r > u_norm * (1.0 + 1e-5) + 1e-12 {
                    return Err(format!("{}: residual {r} > ‖u‖² {u_norm}", op.name()));
                }
            }
            Ok(())
        });
    }
}
