//! The sparsification operator zoo (the paper's §3.3 and §4.3):
//!
//! * [`TopK`] — exact top-k selection by |value| (the `Top_k` operator).
//! * [`RandK`] — uniform random-k (`Rand_k`).
//! * [`DgcK`] — DGC's hierarchical-sampling approximate top-k (Lin et al.
//!   2018), the paper's main approximate baseline.
//! * [`TrimmedK`] — RedSync's max/mean-ratio threshold search (Fang et al.
//!   2019), which may select far more than k elements.
//! * [`GaussianK`] — the paper's contribution (Algorithm 1): Gaussian
//!   percent-point-function threshold estimation with a bounded ±50%
//!   refinement loop.
//!
//! All operators implement [`Compressor`]: they take the error-compensated
//! accumulation `u = g + ε` and a *per-step* target `k` (resolved by the
//! [`crate::schedule`] plan engine — k is no longer operator state) and
//! return a [`SparseVec`] whose kept values are *unchanged* coordinates of
//! `u` (a defining invariant, tested by the property suite).
//!
//! ## The `Workspace` contract
//!
//! [`Compressor::compress_step`] draws every O(d) scratch buffer (the
//! |u| quickselect copy, the Gaussian_k strided sample, tie/pair staging)
//! and its O(k) output buffers from a caller-owned [`Workspace`], so a
//! steady-state step performs **zero heap allocation** once the workspace
//! is warm. (One scoped exception: [`RandK`]'s index sampling draws an
//! O(k) buffer through `Pcg64::sample_indices` each call — its draw order
//! is part of the reproducibility contract, so it is left untouched.)
//! Rules:
//!
//! * One `Workspace` per worker (it is plain owned state — `Send`, no
//!   sharing); any operator may be called with any workspace, in any
//!   order — a `Workspace` carries no per-operator semantics, only
//!   capacity.
//! * Scratch contents are *undefined* between calls; implementations
//!   must fully overwrite what they read.
//! * Output buffers are handed out by [`Workspace::out_buffers`] and come
//!   back through [`Workspace::recycle`] once the payload has been
//!   consumed (the trainer recycles after the collective); skipping
//!   `recycle` is safe — it only costs a fresh allocation next step.
//!
//! ## Warm vs cold selection (`select = exact | warm:TAU`)
//!
//! The thresholded operators ([`TopK`], [`GaussianK`]) additionally
//! expose their per-step threshold derivation via
//! [`Compressor::cold_threshold`], which the warm engine
//! ([`warm::WarmSelector`]) uses as the *seed* of a cross-step
//! [`warm::ThresholdCache`]. State machine per selection domain
//! (monolithic gradient or bucket):
//!
//! ```text
//!   cold ──seed: cold_threshold──► warm(pivot)
//!   warm: one fused scan against the cached pivot
//!         hits ∈ [k, (1+τ)k]  → HIT: O(hits) truncation to exactly k
//!         hits > (1+τ)k       → drift: truncation still (no rescan),
//!                               pivot refreshed from the hits
//!         hits < k            → MISS: full quickselect rescan,
//!                               pivot refreshed at rank ⌈k(1+τ/2)⌉
//! ```
//!
//! The fused scan folds the adaptive-δ |u| histogram and the Σu² mass
//! apportionment statistics into the same pass (see [`warm`] for the
//! full contract). `select = exact` (the default) never touches any of
//! this: every operator runs its original cold path, bit-identically to
//! the pre-warm code.

mod dgc;
mod gaussian;
mod randk;
mod topk;
mod trimmed;
pub mod warm;

pub use dgc::DgcK;
pub use gaussian::{GaussianK, GaussianKConfig};
pub use randk::RandK;
pub use topk::TopK;
pub use trimmed::TrimmedK;
pub use warm::{ThresholdCache, WarmSelector, WarmStats};

use crate::tensor::SparseVec;

/// Reusable per-worker scratch for the compression hot path (see the
/// module docs for the contract). All O(d) working memory lives here so
/// operators themselves stay stateless apart from their RNG streams.
#[derive(Debug, Default)]
pub struct Workspace {
    /// |u| scratch (TopK/DGC quickselect).
    pub(crate) abs: Vec<f32>,
    /// Strided-sample scratch (GaussianK's large-d refinement path).
    pub(crate) sample: Vec<f32>,
    /// Tie-break index staging (TopK).
    pub(crate) ties: Vec<u32>,
    /// (index, value) staging (TopK ordering, DGC candidate truncation).
    pub(crate) pairs: Vec<(u32, f32)>,
    /// Cached identity indices 0..d (Dense's borrowed representation —
    /// built once per dimension, then memcpy'd).
    identity: Vec<u32>,
    /// Recycled output buffers (indices/values pairs).
    free: Vec<(Vec<u32>, Vec<f32>)>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A cleared (indices, values) output pair with at least `cap`
    /// reserved — recycled from a previous payload when available.
    pub(crate) fn out_buffers(&mut self, cap: usize) -> (Vec<u32>, Vec<f32>) {
        let (mut indices, mut values) = self.free.pop().unwrap_or_default();
        indices.clear();
        values.clear();
        indices.reserve(cap);
        values.reserve(cap);
        (indices, values)
    }

    /// Return a consumed payload's buffers to the free list (the trainer
    /// calls this after the collective). Bounded so a one-off dense-sized
    /// payload cannot pin memory forever.
    pub fn recycle(&mut self, payload: SparseVec) {
        if self.free.len() < 8 {
            self.free.push((payload.indices, payload.values));
        }
    }

    /// The identity index prefix `0..d`, cached across calls.
    pub(crate) fn identity(&mut self, d: usize) -> &[u32] {
        if self.identity.len() < d {
            let start = self.identity.len() as u32;
            self.identity.extend(start..d as u32);
        }
        &self.identity[..d]
    }
}

/// A gradient sparsifier. `compress_step` must return coordinates of `u`
/// unchanged; implementations aim for ~`k` non-zeros (exact for
/// [`TopK`]/[`RandK`], approximate for the threshold-based operators) and
/// every *sparse* operator must treat `k == 0` as "send nothing".
/// [`Dense`] is the documented exception: it is the identity operator,
/// ignores `k` entirely, and is never routed through sparse k budgets
/// (the trainer's `is_dense` paths bypass bucket apportionment). The
/// per-step `k` comes from the schedule plan
/// ([`crate::schedule::Scheduler`]); operators hold no target-k state of
/// their own.
pub trait Compressor: Send {
    /// Sparsify `u` (the error-compensated gradient accumulation) to
    /// ~`k` non-zeros using `ws` for all scratch and output buffers.
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec;

    /// The operator's cold-start threshold derivation, used by the warm
    /// engine ([`warm::WarmSelector`]) to seed its cross-step cache:
    /// TopK's exact quickselect pivot, GaussianK's fitted + refined
    /// threshold. `None` (the default) marks an operator with no
    /// threshold concept — warm selection then delegates to
    /// `compress_step` unchanged.
    fn cold_threshold(&mut self, _u: &[f32], _k: usize, _ws: &mut Workspace) -> Option<f32> {
        None
    }

    /// Operator name for reports (matches the paper's terminology).
    fn name(&self) -> &'static str;
}

/// Identity "compressor" for Dense-SGD: keeps everything (`k` ignored).
/// Exists so the trainer can treat Dense/TopK/... uniformly; the
/// collectives layer routes Dense through ring-allreduce rather than
/// allgather. Uses the workspace's cached identity indices, so repeat
/// calls are two memcpys with no index-vector rebuild.
pub struct Dense;

impl Compressor for Dense {
    fn compress_step(&mut self, u: &[f32], _k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let (mut indices, mut values) = ws.out_buffers(d);
        indices.extend_from_slice(ws.identity(d));
        values.extend_from_slice(u);
        SparseVec { d, indices, values }
    }

    fn name(&self) -> &'static str {
        "dense"
    }
}

/// Operator selector used by configs / CLIs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Dense,
    TopK,
    RandK,
    Dgc,
    Trimmed,
    GaussianK,
}

impl OpKind {
    pub fn parse(s: &str) -> anyhow::Result<OpKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => OpKind::Dense,
            "topk" | "top-k" | "top_k" => OpKind::TopK,
            "randk" | "rand-k" | "rand_k" => OpKind::RandK,
            "dgc" | "dgck" | "dgc_k" => OpKind::Dgc,
            "trimmed" | "trimmedk" | "redsync" => OpKind::Trimmed,
            "gaussiank" | "gaussian-k" | "gaussian_k" | "gaussian" => OpKind::GaussianK,
            other => anyhow::bail!("unknown operator '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Dense => "dense",
            OpKind::TopK => "topk",
            OpKind::RandK => "randk",
            OpKind::Dgc => "dgc",
            OpKind::Trimmed => "trimmed",
            OpKind::GaussianK => "gaussiank",
        }
    }

    /// Instantiate an operator with a deterministic seed (used by the
    /// stochastic operators). The per-step k arrives at `compress_step`
    /// time from the schedule plan.
    pub fn build(&self, seed: u64) -> Box<dyn Compressor> {
        match self {
            OpKind::Dense => Box::new(Dense),
            OpKind::TopK => Box::new(TopK::new()),
            OpKind::RandK => Box::new(RandK::new(seed)),
            OpKind::Dgc => Box::new(DgcK::new(0.01, seed)),
            OpKind::Trimmed => Box::new(TrimmedK::new()),
            OpKind::GaussianK => Box::new(GaussianK::new()),
        }
    }

    pub fn all() -> &'static [OpKind] {
        &[
            OpKind::Dense,
            OpKind::TopK,
            OpKind::RandK,
            OpKind::Dgc,
            OpKind::Trimmed,
            OpKind::GaussianK,
        ]
    }

    /// Operators the warm-threshold engine (`select = warm:TAU`) applies
    /// to: the thresholded selections with a [`Compressor::cold_threshold`]
    /// to cache. Every other operator keeps its exact selection even
    /// under a warm config.
    pub fn warm_eligible(&self) -> bool {
        matches!(self, OpKind::TopK | OpKind::GaussianK)
    }
}

/// Shared helper: gather all elements with |u[i]| > thres into a sparse
/// vector (single pass; the L3 twin of the Pallas mask kernel's pass 2).
/// `size_hint` pre-sizes the output (the Gaussian_k refinement loop knows
/// the count before selecting — EXPERIMENTS.md §Perf); output buffers come
/// from the workspace.
pub(crate) fn select_above_hint(
    u: &[f32],
    thres: f32,
    size_hint: usize,
    ws: &mut Workspace,
) -> SparseVec {
    let cap = size_hint.min(u.len());
    let (mut indices, mut values) = ws.out_buffers(cap);
    // Skip-fast: scan 32-wide blocks with two independent vectorizable
    // max-|v| chains and only fall into the scalar gather when the block
    // contains a hit. At k/d ≈ 0.1% the scalar path touches ~3% of blocks,
    // so the sweep approaches pure-load bandwidth (EXPERIMENTS.md §Perf).
    let blocks = u.chunks_exact(32);
    let rem_start = u.len() - blocks.remainder().len();
    for (b, block) in blocks.enumerate() {
        let (mut m0, mut m1) = (0.0f32, 0.0f32);
        for j in 0..16 {
            m0 = m0.max(block[j].abs());
            m1 = m1.max(block[16 + j].abs());
        }
        if m0.max(m1) > thres {
            let base = b * 32;
            for (j, &v) in block.iter().enumerate() {
                if v.abs() > thres {
                    indices.push((base + j) as u32);
                    values.push(v);
                }
            }
        }
    }
    for (j, &v) in u[rem_start..].iter().enumerate() {
        if v.abs() > thres {
            indices.push((rem_start + j) as u32);
            values.push(v);
        }
    }
    SparseVec {
        d: u.len(),
        indices,
        values,
    }
}

pub(crate) fn select_above(u: &[f32], thres: f32, ws: &mut Workspace) -> SparseVec {
    select_above_hint(u, thres, 16, ws)
}

/// Shared helper: count elements with |u[i]| > thres (pass-only, no
/// allocation — the refinement loop of Gaussian_k uses this). Chunked
/// u32 accumulation so the compare+add vectorizes (≈4× over the naive
/// usize-sum version; EXPERIMENTS.md §Perf).
pub(crate) fn count_above(u: &[f32], thres: f32) -> usize {
    let mut total = 0usize;
    // u32 lanes can't overflow within a 1M-element chunk.
    for chunk in u.chunks(1 << 20) {
        let mut acc = [0u32; 8];
        let lanes = chunk.chunks_exact(8);
        let rem = lanes.remainder();
        for l in lanes {
            for j in 0..8 {
                acc[j] += (l[j].abs() > thres) as u32;
            }
        }
        total += acc.iter().sum::<u32>() as usize
            + rem.iter().filter(|v| v.abs() > thres).count();
    }
    total
}

/// Strided count estimate: counts every `stride`-th element and scales.
/// The Gaussian_k refinement only needs the count to ~±15% (its acceptance
/// band is [2k/3, 4k/3]), so at large d a 1/stride sample gives the same
/// refinement decisions at 1/stride of the memory traffic.
pub(crate) fn count_above_strided(u: &[f32], thres: f32, stride: usize) -> usize {
    if stride <= 1 {
        return count_above(u, thres);
    }
    let mut c = 0usize;
    let mut i = 0;
    while i < u.len() {
        c += (u[i].abs() > thres) as usize;
        i += stride;
    }
    c * stride
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    fn ops_under_test() -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(TopK::new()),
            Box::new(RandK::new(7)),
            Box::new(DgcK::new(0.01, 7)),
            Box::new(TrimmedK::new()),
            Box::new(GaussianK::new()),
        ]
    }

    #[test]
    fn opkind_parse_roundtrip() {
        for op in OpKind::all() {
            assert_eq!(OpKind::parse(op.name()).unwrap(), *op);
        }
        assert!(OpKind::parse("nope").is_err());
    }

    #[test]
    fn dense_keeps_everything() {
        let u = vec![1.0f32, -2.0, 0.0, 3.0];
        let mut ws = Workspace::new();
        let s = Dense.compress_step(&u, 1, &mut ws);
        assert_eq!(s.to_dense(), u);
        // Repeat call reuses the cached identity prefix and recycled
        // buffers (behavioural check: output is identical).
        ws.recycle(s);
        let s2 = Dense.compress_step(&u, 1, &mut ws);
        assert_eq!(s2.to_dense(), u);
        assert_eq!(s2.indices, vec![0, 1, 2, 3]);
    }

    #[test]
    fn workspace_recycles_buffers() {
        let mut ws = Workspace::new();
        let (mut i1, v1) = ws.out_buffers(4);
        i1.push(42);
        ws.recycle(SparseVec { d: 8, indices: i1, values: v1 });
        let (i2, _v2) = ws.out_buffers(2);
        // Recycled buffer comes back cleared with its capacity intact.
        assert!(i2.is_empty());
        assert!(i2.capacity() >= 4);
    }

    #[test]
    fn zero_k_sends_nothing() {
        let u = vec![1.0f32, -2.0, 3.0];
        let mut ws = Workspace::new();
        for op in ops_under_test().iter_mut() {
            let s = op.compress_step(&u, 0, &mut ws);
            assert_eq!(s.nnz(), 0, "{}: k = 0 must send nothing", op.name());
            assert_eq!(s.d, u.len());
        }
    }

    #[test]
    fn select_and_count_agree() {
        let mut rng = Pcg64::seed(1);
        let mut ws = Workspace::new();
        let u: Vec<f32> = (0..10_000).map(|_| rng.next_gaussian() as f32).collect();
        for &t in &[0.0f32, 0.5, 1.0, 2.5, 10.0] {
            let s = select_above(&u, t, &mut ws);
            assert_eq!(s.nnz(), count_above(&u, t));
            assert!(s.values.iter().all(|v| v.abs() > t));
            ws.recycle(s);
        }
    }

    /// Invariant: kept values are unchanged coordinates of u, at their
    /// original indices, with no duplicates (all operators), for per-step
    /// k values that *vary between calls* on a shared workspace.
    #[test]
    fn prop_values_unchanged() {
        testkit::forall("values-unchanged", |g: &mut Gen| {
            let d = g.usize_in(16, 4096);
            let u = g.mixed_vec(d);
            let mut ws = Workspace::new();
            for op in ops_under_test().iter_mut() {
                // Two calls with different k exercise workspace reuse.
                for _ in 0..2 {
                    let k = g.usize_in(1, d);
                    let s = op.compress_step(&u, k, &mut ws);
                    let mut seen = std::collections::HashSet::new();
                    for (&i, &v) in s.indices.iter().zip(&s.values) {
                        if i as usize >= d {
                            return Err(format!("{}: index {i} out of range", op.name()));
                        }
                        if !seen.insert(i) {
                            return Err(format!("{}: duplicate index {i}", op.name()));
                        }
                        if u[i as usize].to_bits() != v.to_bits() {
                            return Err(format!(
                                "{}: value changed at {i}: {} -> {v}",
                                op.name(),
                                u[i as usize]
                            ));
                        }
                    }
                    ws.recycle(s);
                }
            }
            Ok(())
        });
    }

    /// Invariant: residual + compressed == u exactly (error-feedback
    /// decomposition, Eq. 2 of the paper).
    #[test]
    fn prop_exact_decomposition() {
        testkit::forall("exact-decomposition", |g: &mut Gen| {
            let d = g.usize_in(16, 2048);
            let k = g.usize_in(1, d / 2 + 1);
            let mu = g.f32_in(-1.0, 1.0);
            let sigma = g.f32_in(0.01, 2.0);
            let u = g.gaussian_vec(d, mu, sigma);
            let mut ws = Workspace::new();
            for op in ops_under_test().iter_mut() {
                let s = op.compress_step(&u, k, &mut ws);
                let dense = s.to_dense();
                let resid: Vec<f32> = u.iter().zip(&dense).map(|(a, b)| a - b).collect();
                let recon: Vec<f32> = resid.iter().zip(&dense).map(|(a, b)| a + b).collect();
                testkit::assert_allclose(&recon, &u, 0.0, 0.0)
                    .map_err(|e| format!("{}: {e}", op.name()))?;
            }
            Ok(())
        });
    }

    /// Contraction property (3): ‖u − C(u)‖² ≤ ‖u‖² for every operator
    /// (trivially true since values are kept unchanged, but guards against
    /// sign/scale bugs).
    #[test]
    fn prop_contraction() {
        testkit::forall("contraction", |g: &mut Gen| {
            let d = g.usize_in(16, 2048);
            let k = g.usize_in(1, d);
            let u = g.mixed_vec(d);
            let u_norm = crate::stats::norm2_sq(&u);
            let mut ws = Workspace::new();
            for op in ops_under_test().iter_mut() {
                let s = op.compress_step(&u, k, &mut ws);
                let dense = s.to_dense();
                let resid: Vec<f32> = u.iter().zip(&dense).map(|(a, b)| a - b).collect();
                let r = crate::stats::norm2_sq(&resid);
                if r > u_norm * (1.0 + 1e-5) + 1e-12 {
                    return Err(format!("{}: residual {r} > ‖u‖² {u_norm}", op.name()));
                }
            }
            Ok(())
        });
    }
}
