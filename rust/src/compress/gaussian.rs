//! `Gaussian_k` — the paper's contribution (Algorithm 1).
//!
//! Exploits the empirical bell shape of the error-compensated gradient
//! `u = g + ε` (paper §3.1, Fig. 2): estimate the top-k threshold as the
//! Gaussian percent-point function at p = 1 − k/d with the vector's own
//! (μ, σ), then refine at most 4 times by ±50% until the selected count
//! lands in [2k/3, 4k/3]. Total cost: one fused mean/std pass + at most
//! five count/mask passes — all O(d), branch-predictable, and vector-
//! friendly, vs. exact selection's data-dependent partitioning.
//!
//! Faithfulness notes:
//! * Line 4 of Algorithm 1 thresholds the *signed* ppf but masks on
//!   |u| > thres; for a symmetric distribution that initially selects
//!   ≈ 2k elements, which the ×1.5 refinement then corrects. We keep the
//!   paper's exact behaviour by default; [`GaussianKConfig::two_sided_init`]
//!   enables the analytically-correct |·| quantile (p = 1 − k/(2d)) as an
//!   ablation (bench `fig4_operator_speed --ablation`).
//! * The paper's operator can return 0 elements on pathological (σ≈0 or
//!   extremely spiky) inputs. For training robustness we add an explicit
//!   exact-top-k fallback when the refinement ends empty; fallbacks are
//!   counted and reported ([`GaussianK::fallbacks`]), and the numerical
//!   studies show it never triggers on real bell-shaped gradients.
//!
//! The per-step k comes from the schedule plan; the strided-sample
//! scratch lives in the caller's [`Workspace`], so a varying k never
//! costs a reallocation.

use super::{count_above, count_above_strided, select_above_hint, Compressor, Workspace};
use crate::stats::{mean_std, normal::ppf};
use crate::tensor::SparseVec;

/// Tuning knobs for [`GaussianK`]. Defaults = Algorithm 1 as published.
#[derive(Debug, Clone)]
pub struct GaussianKConfig {
    /// Max refinement iterations (paper: 4).
    pub max_iters: usize,
    /// Accept when count ∈ [lo_frac·k, hi_frac·k] (paper: 2/3, 4/3).
    pub lo_frac: f64,
    pub hi_frac: f64,
    /// Multiplier when over-selecting (paper: 1.5).
    pub up: f32,
    /// Multiplier when under-selecting (paper: 0.5).
    pub down: f32,
    /// Use the two-sided |·| quantile p = 1 − k/(2d) for the initial
    /// threshold instead of the paper's one-sided p = 1 − k/d.
    pub two_sided_init: bool,
    /// Fall back to exact top-k if refinement ends with zero selected.
    pub exact_fallback: bool,
    /// Refinement-count sampling stride: 0 = auto (exact below 4M
    /// elements, strided above — the counts only steer the ±50% loop, so
    /// a 1/stride sample changes nothing at k ≫ stride while cutting the
    /// loop's memory traffic by ~stride; EXPERIMENTS.md §Perf). 1 = always
    /// exact (the published algorithm's literal cost model).
    pub count_stride: usize,
}

impl Default for GaussianKConfig {
    fn default() -> Self {
        GaussianKConfig {
            max_iters: 4,
            lo_frac: 2.0 / 3.0,
            hi_frac: 4.0 / 3.0,
            up: 1.5,
            down: 0.5,
            two_sided_init: false,
            exact_fallback: true,
            count_stride: 0,
        }
    }
}

/// The Gaussian_k approximate top-k operator (Algorithm 1).
#[derive(Debug, Default)]
pub struct GaussianK {
    pub cfg: GaussianKConfig,
    /// Number of times the exact-top-k fallback fired (diagnostics).
    pub fallbacks: u64,
    /// Number of threshold-refinement iterations used, cumulative
    /// (diagnostics; Fig. 10's under/over-sparsification study reads the
    /// per-call selected counts from the trainer's metrics instead).
    pub refine_iters: u64,
}

impl GaussianK {
    pub fn new() -> GaussianK {
        GaussianK::default()
    }

    pub fn with_config(cfg: GaussianKConfig) -> GaussianK {
        GaussianK {
            cfg,
            fallbacks: 0,
            refine_iters: 0,
        }
    }

    /// The estimated threshold after refinement, plus the selected count —
    /// exposed for the analysis harnesses and the PJRT cross-check test
    /// (kernel parity with the Pallas implementation).
    pub fn refined_threshold(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> (f32, usize) {
        let d = u.len();
        let k = k.min(d).max(1);
        let (mu, sigma) = mean_std(u);
        if sigma == 0.0 || !sigma.is_finite() || !mu.is_finite() {
            // Degenerate point mass (all-zero or constant gradient): no
            // Gaussian fit exists and no threshold can separate equal
            // magnitudes. Report the point's magnitude as a finite
            // threshold with a zero count so `compress_step` routes to
            // the exact fallback, which sends exactly min(k, d) elements.
            return (mu.abs(), 0);
        }
        let p = if self.cfg.two_sided_init {
            1.0 - (k as f64) / (2.0 * d as f64)
        } else {
            1.0 - (k as f64) / (d as f64)
        };
        // Algorithm 1 line 4: thres = ppf(p; μ, σ). For the two-sided
        // variant we center on |u − μ| ≈ ppf offset; the paper's version
        // uses the signed quantile directly.
        let mut thres = ppf(p, mu as f64, sigma as f64) as f32;
        if !thres.is_finite() || thres <= 0.0 {
            // σ = 0 or k ≈ d: degenerate — every |u| > 0 qualifies.
            thres = 0.0;
        }
        let lo = (self.cfg.lo_frac * k as f64) as usize;
        let hi = (self.cfg.hi_frac * k as f64).ceil() as usize;
        // Auto stride: exact counting when the sample would be too small
        // for the ±33% band decision (need ≳ 1000 expected hits), strided
        // otherwise. k/stride ≥ 1024 ⇒ sampling noise ≈ 3% ≪ band width.
        let stride = match self.cfg.count_stride {
            0 => {
                if d >= 4_000_000 && k >= 64 * 1024 / 16 {
                    (k / 1024).clamp(1, 64)
                } else {
                    1
                }
            }
            s => s,
        };
        // With stride > 1, materialize the strided sample ONCE into the
        // workspace scratch: the ≤4 refinement counts then run over a
        // d/stride-element buffer at cache speed instead of issuing
        // cache-missing strided loads per iteration (EXPERIMENTS.md §Perf).
        if stride > 1 {
            ws.sample.clear();
            ws.sample.reserve(d / stride + 1);
            let mut i = 0;
            while i < d {
                ws.sample.push(u[i]);
                i += stride;
            }
        }
        let sample: &[f32] = &ws.sample;
        // Algorithm 1 lines 5–13: evaluate the mask *first*, then adjust.
        // The mask used for the output is the last *evaluated* one — if the
        // loop exhausts right after an adjustment, the adjusted threshold
        // is never applied (faithful to the published pseudocode, and the
        // source of Fig. 10's under/over-sparsification).
        let mut eval_thres = thres;
        let mut count = 0usize;
        for _ in 0..self.cfg.max_iters {
            self.refine_iters += 1;
            eval_thres = thres;
            count = if stride > 1 {
                count_above(sample, eval_thres) * stride
            } else {
                count_above_strided(u, eval_thres, 1)
            };
            if count < lo.max(1) {
                thres = eval_thres * self.cfg.down;
            } else if count > hi {
                thres = eval_thres * self.cfg.up;
            } else {
                break;
            }
        }
        if count >= d && k < d {
            // The refinement collapsed below every magnitude (σ ≈ 0
            // within float noise — e.g. a constant vector whose fitted σ
            // is rounding residue): the threshold separates nothing, so
            // the selection pass would keep all d elements for a k-sized
            // budget. Degenerate — route to the exact fallback.
            return (mu.abs(), 0);
        }
        // With stride > 1 the returned count is the (scaled) estimate —
        // callers only use it as a capacity hint and an emptiness check;
        // the actual selection pass is exact regardless. (An exact
        // reconciliation pass here would cost a full d-sweep and buy
        // nothing: compress_step materializes the exact set anyway.)
        (eval_thres, count)
    }
}

impl Compressor for GaussianK {
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return SparseVec::new(d);
        }
        if k == d {
            return super::Dense.compress_step(u, k, ws);
        }
        let (thres, count) = self.refined_threshold(u, k, ws);
        if count == 0 {
            // Exact fallback covers both the spiky case and the σ = 0
            // point masses (all-zero / constant gradients), where TopK's
            // tie-breaking yields exactly min(k, d) elements — the
            // degenerate-distribution contract.
            if self.cfg.exact_fallback {
                self.fallbacks += 1;
                return super::TopK::new().compress_step(u, k, ws);
            }
            return SparseVec::new(d);
        }
        select_above_hint(u, thres, count, ws)
    }

    fn cold_threshold(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> Option<f32> {
        // The warm engine's seed: the fitted + refined threshold. A
        // degenerate fit reports its point magnitude (count 0), which is
        // still a valid scan threshold — the warm band check then routes
        // to its own exact rescan.
        Some(self.refined_threshold(u, k, ws).0.max(0.0))
    }

    fn name(&self) -> &'static str {
        "gaussiank"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    #[test]
    fn selects_near_k_on_gaussian() {
        // The paper's one-sided ppf init + ×0.5/×1.5 refinement genuinely
        // oscillates on exact Gaussians (the under/over-sparsification the
        // paper itself documents in Fig. 10), so the faithful operator
        // lands within a ~3× band of k, not the acceptance band itself.
        let mut rng = Pcg64::seed(40);
        let d = 1_000_000;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let k = d / 1000; // the paper's k = 0.001 d
        let mut op = GaussianK::new();
        let s = op.compress_step(&u, k, &mut Workspace::new());
        assert!(
            s.nnz() >= k / 3 && s.nnz() <= 3 * k,
            "nnz {} vs k {k}",
            s.nnz()
        );
        assert_eq!(op.fallbacks, 0);
    }

    #[test]
    fn two_sided_init_hits_acceptance_band() {
        // The analytically-correct |·| quantile lands inside the paper's
        // acceptance band [2k/3, 4k/3] immediately on true Gaussians.
        let mut rng = Pcg64::seed(45);
        let d = 1_000_000;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let k = d / 1000;
        let mut op = GaussianK::with_config(GaussianKConfig {
            two_sided_init: true,
            ..Default::default()
        });
        let s = op.compress_step(&u, k, &mut Workspace::new());
        assert!(
            s.nnz() >= 2 * k / 3 && s.nnz() <= 4 * k / 3 + 1,
            "nnz {} vs k {k}",
            s.nnz()
        );
    }

    #[test]
    fn captures_topk_energy() {
        // The selected set must capture nearly the exact top-k energy: this
        // is the convergence-preservation claim (Fig. 6).
        let mut rng = Pcg64::seed(41);
        let d = 200_000;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let k = 200;
        let mut ws = Workspace::new();
        let exact = super::super::TopK::new().compress_step(&u, k, &mut ws);
        let approx = GaussianK::new().compress_step(&u, k, &mut ws);
        let ratio = approx.norm2_sq() / exact.norm2_sq();
        // A single Gaussian_k call can land on the under-selecting side of
        // the oscillating refinement (≈ half the exact energy); error
        // feedback recovers the remainder across steps (Fig. 6 parity is
        // tested end-to-end in coordinator::trainer).
        assert!(ratio > 0.4, "energy ratio {ratio}");
    }

    #[test]
    fn nonzero_mean_and_scale_invariance() {
        let mut rng = Pcg64::seed(42);
        let d = 100_000;
        let k = 100;
        for &(mu, sigma) in &[(5.0f64, 0.1f64), (-3.0, 2.0), (0.0, 1e-4)] {
            let u: Vec<f32> = (0..d)
                .map(|_| (mu + sigma * rng.next_gaussian()) as f32)
                .collect();
            let mut op = GaussianK::new();
            let s = op.compress_step(&u, k, &mut Workspace::new());
            assert!(s.nnz() > 0, "mu={mu} sigma={sigma}: empty selection");
        }
    }

    #[test]
    fn laplace_still_works() {
        // Bell-shaped but heavier-tailed than Gaussian (LSTM-like, Fig. 2):
        // the refinement loop must still land near k.
        let mut rng = Pcg64::seed(43);
        let d = 500_000;
        let u: Vec<f32> = (0..d).map(|_| rng.next_laplace(0.0, 0.5) as f32).collect();
        let k = 500;
        let mut op = GaussianK::new();
        let s = op.compress_step(&u, k, &mut Workspace::new());
        // Heavy tails stretch the ±50% refinement further than on true
        // Gaussians: the operator over-communicates by up to ~8× here,
        // exactly the Fig. 10 over/under-sparsification behaviour.
        assert!(
            s.nnz() >= k / 6 && s.nnz() <= 8 * k,
            "nnz {} vs k {k}",
            s.nnz()
        );
    }

    #[test]
    fn fallback_on_degenerate_input() {
        let mut u = vec![0.0f32; 10_000];
        u[5] = 1.0; // single spike, σ≈0.01, ppf threshold lands above |1.0|? Actually exercise it.
        let mut op = GaussianK::new();
        let mut ws = Workspace::new();
        let s = op.compress_step(&u, 10, &mut ws);
        assert!(s.nnz() >= 1, "must select the spike (possibly via fallback)");
        assert!(s.indices.contains(&5), "the spike coordinate must be kept");
        // All-zero gradient: σ = 0, no fit — the exact fallback still
        // emits exactly min(k, d) (zero-valued) elements, matching TopK's
        // tie-break contract.
        let zero = vec![0.0f32; 100];
        let mut op2 = GaussianK::new();
        let s = op2.compress_step(&zero, 5, &mut ws);
        assert_eq!(s.nnz(), 5);
        assert!(s.values.iter().all(|&v| v == 0.0));
        assert_eq!(op2.fallbacks, 1);
    }

    #[test]
    fn degenerate_sigma_zero_sends_exactly_min_k_d() {
        let mut ws = Workspace::new();
        // All-zero: finite threshold, exactly min(k, d) elements.
        let zero = vec![0.0f32; 100];
        let mut op = GaussianK::new();
        let (t, c) = op.refined_threshold(&zero, 5, &mut ws);
        assert!(t.is_finite());
        assert_eq!(c, 0);
        assert_eq!(op.compress_step(&zero, 5, &mut ws).nnz(), 5);
        // Constant positive gradient (σ = 0 exactly at power-of-two d).
        let c_pos = vec![3.5f32; 64];
        let mut op = GaussianK::new();
        let (t, c) = op.refined_threshold(&c_pos, 7, &mut ws);
        assert!(t.is_finite());
        assert_eq!(c, 0);
        let s = op.compress_step(&c_pos, 7, &mut ws);
        assert_eq!(s.nnz(), 7, "constant vector must send exactly k");
        assert!(s.values.iter().all(|&v| v == 3.5));
        assert_eq!(s.indices, (0..7).collect::<Vec<u32>>());
        // Constant negative gradient: the old ppf clamp (thres = 0)
        // selected all d elements here.
        let c_neg = vec![-2.0f32; 64];
        let mut op = GaussianK::new();
        let s = op.compress_step(&c_neg, 7, &mut ws);
        assert_eq!(s.nnz(), 7);
        assert!(s.values.iter().all(|&v| v == -2.0));
        // Constant at a non-power-of-two d (fitted σ may be rounding
        // residue instead of exact zero — the post-refinement count ≥ d
        // guard must still route to the fallback).
        let c_odd = vec![0.7f32; 101];
        let mut op = GaussianK::new();
        let s = op.compress_step(&c_odd, 9, &mut ws);
        assert_eq!(s.nnz(), 9);
        // k ≥ d on a degenerate vector keeps everything.
        let s = GaussianK::new().compress_step(&c_neg, 100, &mut ws);
        assert_eq!(s.nnz(), 64);
    }

    #[test]
    fn two_sided_ablation_starts_closer() {
        // The two-sided init should need fewer refinement iterations on a
        // symmetric Gaussian (it corrects the 2× over-selection analytically).
        let mut rng = Pcg64::seed(44);
        let d = 500_000;
        let u: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let k = 500;
        let mut ws = Workspace::new();
        let mut paper = GaussianK::new();
        let mut two_sided = GaussianK::with_config(GaussianKConfig {
            two_sided_init: true,
            ..Default::default()
        });
        paper.compress_step(&u, k, &mut ws);
        two_sided.compress_step(&u, k, &mut ws);
        assert!(
            two_sided.refine_iters <= paper.refine_iters,
            "two-sided {} vs paper {}",
            two_sided.refine_iters,
            paper.refine_iters
        );
    }

    #[test]
    fn prop_selection_band_on_bell_shapes() {
        testkit::forall("gaussiank-band", |g: &mut Gen| {
            let d = g.usize_in(10_000, 80_000);
            let k = (d / g.usize_in(100, 1000)).max(8);
            let sigma = g.f32_in(1e-3, 5.0);
            // Real gradient accumulations are near-zero-mean relative to
            // their spread (Fig. 2); the one-sided ppf init degrades
            // gracefully but unboundedly as |mu|/sigma grows.
            let mu = g.f32_in(-0.3, 0.3) * sigma;
            let u = g.gaussian_vec(d, mu, sigma);
            let mut op = GaussianK::new();
            let s = op.compress_step(&u, k, &mut Workspace::new());
            // Generous band after ≤4 coarse ±50% refinements: within ~6×.
            if s.nnz() < k / 6 || s.nnz() > 6 * k {
                return Err(format!("d={d} k={k} mu={mu} sigma={sigma}: nnz {}", s.nnz()));
            }
            Ok(())
        });
    }

    /// Theorem-1 premise check: on bell-shaped u the Gaussian_k residual
    /// satisfies the paper's (1−k/d)² bound (it keeps ≈ the same mass as
    /// exact top-k).
    #[test]
    fn prop_respects_tight_bound_on_gaussians() {
        testkit::forall("gaussiank-tight-bound", |g: &mut Gen| {
            let d = g.usize_in(20_000, 60_000);
            let k = d / g.usize_in(50, 500);
            let sigma = g.f32_in(0.1, 3.0);
            let u = g.gaussian_vec(d, 0.0, sigma);
            let mut op = GaussianK::new();
            let s = op.compress_step(&u, k.max(1), &mut Workspace::new());
            let u_sq = crate::stats::norm2_sq(&u);
            let resid = u_sq - s.norm2_sq();
            // use the *selected* count as the effective k for the bound
            let keff = s.nnz().min(d);
            let gamma = (1.0 - keff as f64 / d as f64).powi(2);
            if resid > gamma * u_sq * 1.05 {
                return Err(format!(
                    "residual {resid:.4} > (1-k/d)²‖u‖² {:.4} (keff={keff}, d={d})",
                    gamma * u_sq
                ));
            }
            Ok(())
        });
    }
}
