//! `Trimmed_k`: RedSync's trimmed top-k selection (Fang et al. 2019).
//!
//! Heuristic threshold search "moving the ratio between the maximum value
//! and the average value" (paper §3.3): the threshold is
//! `mean + ratio·(max − mean)` and the ratio is walked *down* from 1 in
//! coarse halving steps until at least k elements pass. Because the steps
//! are coarse and gradient tails are heavy, the accepted threshold often
//! admits far more than k elements — the paper's stated failure mode
//! ("the number of selected gradients is much higher than k"), which the
//! Table 2 simulation models as ~10× communication inflation and which
//! the `over_selection_on_heavy_tails` test reproduces on Laplace
//! gradients.

use super::{count_above, select_above, Compressor, Workspace};
use crate::tensor::SparseVec;

/// RedSync-style trimmed threshold search (k arrives per step).
#[derive(Debug)]
pub struct TrimmedK {
    /// Max number of ratio-halving iterations.
    pub max_iters: usize,
}

impl Default for TrimmedK {
    fn default() -> Self {
        TrimmedK { max_iters: 24 }
    }
}

impl TrimmedK {
    pub fn new() -> TrimmedK {
        TrimmedK::default()
    }

    /// The accepted threshold (exposed for diagnostics/benches).
    pub fn search_threshold(&self, u: &[f32], k: usize) -> f32 {
        let d = u.len();
        // mean and max of |u| in one pass.
        let (mut sum, mut maxv) = (0.0f64, 0.0f32);
        for &v in u {
            let a = v.abs();
            sum += a as f64;
            if a > maxv {
                maxv = a;
            }
        }
        let mean = (sum / d.max(1) as f64) as f32;
        if maxv <= 0.0 {
            return f32::INFINITY; // all-zero input: nothing to select
        }
        // Walk ratio down from 1 by halving until ≥ k elements pass.
        let mut ratio = 1.0f32;
        let mut thres = maxv;
        for _ in 0..self.max_iters {
            ratio *= 0.5;
            let cand = mean + ratio * (maxv - mean);
            let c = count_above(u, cand);
            thres = cand;
            if c >= k {
                break; // coarse accept — this is where over-selection is born
            }
        }
        thres
    }
}

impl Compressor for TrimmedK {
    fn compress_step(&mut self, u: &[f32], k: usize, ws: &mut Workspace) -> SparseVec {
        let d = u.len();
        let k = k.min(d);
        if k == 0 {
            return SparseVec::new(d);
        }
        if k == d {
            return super::Dense.compress_step(u, k, ws);
        }
        let thres = self.search_threshold(u, k);
        if !thres.is_finite() {
            return SparseVec::new(d);
        }
        let out = select_above(u, thres, ws);
        if out.nnz() == 0 {
            // Degenerate tie at max (e.g. constant vector): keep the max
            // element(s).
            ws.recycle(out);
            let maxv = u.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let (mut indices, mut values) = ws.out_buffers(16);
            for (i, &v) in u.iter().enumerate() {
                if v.abs() >= maxv {
                    indices.push(i as u32);
                    values.push(v);
                }
            }
            return SparseVec { d, indices, values };
        }
        out
    }

    fn name(&self) -> &'static str {
        "trimmed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;
    use crate::util::testkit::{self, Gen};

    fn trim(u: &[f32], k: usize) -> SparseVec {
        TrimmedK::new().compress_step(u, k, &mut Workspace::new())
    }

    #[test]
    fn selects_some_top_mass() {
        let mut rng = Pcg64::seed(30);
        let u: Vec<f32> = (0..100_000).map(|_| rng.next_gaussian() as f32).collect();
        let k = 100;
        let s = trim(&u, k);
        assert!(s.nnz() >= k, "must select at least k on a smooth vector");
        // Captured energy per element must beat random selection.
        let frac = s.norm2_sq() / crate::stats::norm2_sq(&u);
        assert!(frac > s.nnz() as f64 / u.len() as f64, "no better than random");
    }

    #[test]
    fn over_selection_on_heavy_tails() {
        // Laplace gradients (LSTM-like, paper Fig. 2 bottom rows): the
        // coarse ratio-halving overshoots and selects ≫ k — the paper's
        // stated failure mode for RedSync.
        let mut rng = Pcg64::seed(31);
        let u: Vec<f32> = (0..200_000).map(|_| rng.next_laplace(0.0, 1.0) as f32).collect();
        let k = 500;
        let s = trim(&u, k);
        assert!(
            s.nnz() > 2 * k,
            "expected over-selection, got nnz={} (k={k})",
            s.nnz()
        );
    }

    #[test]
    fn all_zero_input() {
        let u = vec![0.0f32; 1000];
        let s = trim(&u, 10);
        assert_eq!(s.nnz(), 0);
    }

    #[test]
    fn constant_input_degenerate() {
        let u = vec![2.0f32; 100];
        let s = trim(&u, 5);
        // mean == max: the fallback keeps the ties.
        assert!(s.nnz() > 0);
        assert!(s.values.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn prop_valid_selection() {
        testkit::forall("trimmed-valid", |g: &mut Gen| {
            let d = g.usize_in(64, 8192);
            let k = g.usize_in(1, d / 8 + 1);
            let u = g.mixed_vec(d);
            let s = trim(&u, k);
            if s.indices.windows(2).any(|w| w[0] >= w[1]) {
                return Err("indices not sorted-unique".into());
            }
            // Never loses the single biggest element when something was
            // selected and the vector is non-zero.
            if s.nnz() > 0 {
                let amax = u
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap()
                    .0 as u32;
                if u[amax as usize].abs() > 0.0 && !s.indices.contains(&amax) {
                    return Err("dropped the max-magnitude element".into());
                }
            }
            Ok(())
        });
    }
}
