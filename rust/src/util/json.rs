//! Minimal JSON parser/serializer.
//!
//! The AOT manifest (`artifacts/manifest.json`) and all result emitters use
//! JSON; `serde_json` is unavailable offline, so this module implements the
//! subset of JSON we need: objects, arrays, strings (with escapes), f64
//! numbers, booleans and null. Round-trips everything Python's `json`
//! module emits with default settings.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as `f64` (Python's `json` emits only
/// doubles/ints; all our ints fit in the 2^53 exact range).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Ordered map so serialization is deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
///
/// Hand-rolled `Display`/`Error` impls: the crate is dependency-free
/// beyond `anyhow`, so no `thiserror` derive here.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for completeness.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"he\"llo\n","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 😀");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn builder() {
        let mut o = Json::obj();
        o.set("k", Json::from(3usize)).set("s", Json::from("v"));
        assert_eq!(o.to_string(), r#"{"k":3,"s":"v"}"#);
    }

    #[test]
    fn python_style_manifest() {
        // Matches what python's json.dump produces (spaces after : and ,).
        let src = "{\"models\": {\"mlp\": {\"d\": 199210, \"entry\": \"train_step\"}}, \"version\": 1}";
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("models").unwrap().get("mlp").unwrap().get("d").unwrap().as_usize(),
            Some(199210)
        );
    }
}
