//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Provides warm-up, adaptive iteration counts, robust statistics (median +
//! MAD), and a simple text/JSON report. All `rust/benches/*` harnesses use
//! this to regenerate the paper's tables/figures.

use std::time::Instant;

use crate::util::json::Json;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    /// Per-iteration wall time in seconds (one entry per measured batch).
    pub times: Vec<f64>,
    pub iters_per_batch: u64,
}

impl Sample {
    pub fn median(&self) -> f64 {
        percentile(&self.times, 50.0)
    }

    pub fn p10(&self) -> f64 {
        percentile(&self.times, 10.0)
    }

    pub fn p90(&self) -> f64 {
        percentile(&self.times, 90.0)
    }

    pub fn mean(&self) -> f64 {
        self.times.iter().sum::<f64>() / self.times.len().max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from(self.name.clone()))
            .set("median_s", Json::from(self.median()))
            .set("mean_s", Json::from(self.mean()))
            .set("p10_s", Json::from(self.p10()))
            .set("p90_s", Json::from(self.p90()))
            .set("batches", Json::from(self.times.len()));
        o
    }
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    /// Target wall-time spent measuring each case (seconds).
    pub measure_secs: f64,
    /// Target wall-time spent warming up each case (seconds).
    pub warmup_secs: f64,
    /// Minimum number of measured batches.
    pub min_batches: usize,
    pub samples: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_secs: 1.0,
            warmup_secs: 0.2,
            min_batches: 5,
            samples: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new(measure_secs: f64) -> Bench {
        Bench {
            measure_secs,
            ..Bench::default()
        }
    }

    /// Quick-mode constructor honoring the SPARKV_BENCH_FAST env toggle.
    pub fn from_env(default_measure: f64) -> Bench {
        let fast = std::env::var("SPARKV_BENCH_FAST").is_ok();
        Bench::new(if fast { default_measure / 10.0 } else { default_measure })
    }

    /// Time `f`, which performs exactly one logical iteration per call.
    /// Returns per-iteration seconds (median).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Warm-up + calibration: find how many iters fit in ~10ms batches.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_secs || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let batch_iters = ((0.01 / per_iter).ceil() as u64).clamp(1, 1_000_000);

        let mut times = Vec::new();
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.measure_secs || times.len() < self.min_batches {
            let bt = Instant::now();
            for _ in 0..batch_iters {
                f();
            }
            times.push(bt.elapsed().as_secs_f64() / batch_iters as f64);
            if times.len() >= 10_000 {
                break;
            }
        }
        let sample = Sample {
            name: name.to_string(),
            times,
            iters_per_batch: batch_iters,
        };
        let med = sample.median();
        self.samples.push(sample);
        med
    }

    /// Render an aligned text table of all recorded samples.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12}\n",
            "case", "median", "p10", "p90"
        ));
        for s in &self.samples {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12}\n",
                s.name,
                crate::util::human_secs(s.median()),
                crate::util::human_secs(s.p10()),
                crate::util::human_secs(s.p90()),
            ));
        }
        out
    }

    /// Dump all samples as a JSON array (for EXPERIMENTS.md automation).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.samples.iter().map(|s| s.to_json()).collect())
    }

    /// Write the JSON report under `results/` (creating the directory).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            measure_secs: 0.05,
            warmup_secs: 0.01,
            min_batches: 3,
            samples: vec![],
        };
        let mut acc = 0u64;
        let med = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(med > 0.0 && med < 1e-3);
        assert_eq!(b.samples.len(), 1);
        assert!(b.report().contains("noop-ish"));
    }

    #[test]
    fn percentile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
