//! General-purpose substrates built in-tree for the offline environment:
//! a minimal JSON layer, a CLI argument parser, a micro-benchmark harness
//! and a property-testing kit (stand-ins for `serde_json`, `clap`,
//! `criterion` and `proptest`, which are unavailable offline — see
//! DESIGN.md §2).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod testkit;

/// Format a byte count as a human-readable string (e.g. `1.5 MiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(0.5e-9 * 10.0), "5.0 ns");
        assert_eq!(human_secs(1.5e-3), "1.500 ms");
        assert_eq!(human_secs(2.0), "2.000 s");
    }
}
