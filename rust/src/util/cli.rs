//! Tiny CLI argument parser (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands. Each binary declares its options up front so `--help`
//! is generated consistently.

use std::collections::BTreeMap;

/// Declarative option spec for help generation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed command line: subcommand, `--key value` options, bare flags and
/// positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse `std::env::args()`. `with_subcommand` treats the first
    /// positional as a subcommand name.
    pub fn parse_env(with_subcommand: bool) -> Args {
        Self::parse(std::env::args().collect(), with_subcommand)
    }

    /// Parse an explicit argv (index 0 = program name).
    pub fn parse(argv: Vec<String>, with_subcommand: bool) -> Args {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_default(),
            ..Args::default()
        };
        let mut it = argv.into_iter().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if with_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Register an option spec (for `--help` output).
    pub fn spec(&mut self, name: &'static str, help: &'static str, default: Option<&'static str>) {
        self.specs.push(OptSpec {
            name,
            help,
            default,
            is_flag: false,
        });
    }

    /// Register a flag spec (for `--help` output).
    pub fn flag_spec(&mut self, name: &'static str, help: &'static str) {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
    }

    /// True if `--name` was given as a bare flag (or as `--name=true`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a clear message on parse
    /// failure (CLI boundary, so a panic is the right UX).
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("invalid value for --{name}: {s:?}")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(s) => s.split(',').map(|p| p.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Render help text from the registered specs.
    pub fn help(&self, about: &str) -> String {
        let mut out = format!("{about}\n\nUSAGE: {} [OPTIONS]\n\nOPTIONS:\n", self.program);
        for s in &self.specs {
            let head = if s.is_flag {
                format!("  --{}", s.name)
            } else {
                format!("  --{} <value>", s.name)
            };
            let def = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            out.push_str(&format!("{head:<32} {}{def}\n", s.help));
        }
        out
    }

    /// Print help and exit if `--help` was passed.
    pub fn exit_on_help(&self, about: &str) {
        if self.flag("help") {
            println!("{}", self.help(about));
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        // Note: a bare `--flag` followed by a non-dash token is parsed as
        // `--flag token` (option with value) — flags should come last or
        // use `--flag=true`. This matches the documented grammar.
        let a = Args::parse(argv("prog --k 32 --name=test pos1 --verbose"), false);
        assert_eq!(a.get("k"), Some("32"));
        assert_eq!(a.get("name"), Some("test"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        let b = Args::parse(argv("prog --verbose=true pos1"), false);
        assert!(b.flag("verbose"));
        assert_eq!(b.positional, vec!["pos1"]);
    }

    #[test]
    fn subcommand_mode() {
        let a = Args::parse(argv("sparkv train --steps 100"), true);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_parsed_or("steps", 0usize), 100);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(argv("prog"), false);
        assert_eq!(a.get_parsed_or("lr", 0.1f64), 0.1);
        assert_eq!(a.get_or("op", "topk"), "topk");
    }

    #[test]
    fn lists() {
        let a = Args::parse(argv("prog --ops dense,topk, gaussiank"), false);
        // note: the space split means 'gaussiank' is positional; list parsing
        // only applies to the option value
        assert_eq!(a.get_list("ops", &[]), vec!["dense", "topk", ""]);
        let b = Args::parse(argv("prog --ops dense,topk,gaussiank"), false);
        assert_eq!(b.get_list("ops", &[]), vec!["dense", "topk", "gaussiank"]);
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn bad_typed_value_panics() {
        let a = Args::parse(argv("prog --steps abc"), false);
        let _ = a.get_parsed_or("steps", 0usize);
    }

    #[test]
    fn flag_last_token() {
        let a = Args::parse(argv("prog --cdf"), false);
        assert!(a.flag("cdf"));
    }
}
