//! Property-testing kit (offline stand-in for `proptest`).
//!
//! Seeded, deterministic generators plus a `forall` driver that runs N
//! cases and, on failure, reports the seed and a greedily-shrunk input
//! size. Used by the L3 invariant tests (compressor contracts, collective
//! equivalence, error-feedback mass conservation — DESIGN.md §5).

use crate::stats::rng::Pcg64;

/// Number of cases per property (overridable via SPARKV_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("SPARKV_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// A deterministic generator context handed to each test case.
pub struct Gen {
    pub rng: Pcg64,
    pub case: usize,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// A vector of standard-normal f32s (the paper's bell-shaped regime).
    pub fn gaussian_vec(&mut self, d: usize, mu: f32, sigma: f32) -> Vec<f32> {
        (0..d)
            .map(|_| mu + sigma * self.rng.next_gaussian() as f32)
            .collect()
    }

    /// A vector from a zoo of distributions: gaussian, laplace, logistic,
    /// uniform, and a "spiky" mix (mostly-zero plus a few large entries) —
    /// the regimes the paper's Fig. 2 histograms cover, plus adversarial
    /// shapes.
    pub fn mixed_vec(&mut self, d: usize) -> Vec<f32> {
        match self.usize_in(0, 4) {
            0 => {
                let sigma = self.f32_in(1e-4, 10.0);
                self.gaussian_vec(d, 0.0, sigma)
            }
            1 => {
                let b = self.f64_in(1e-4, 5.0);
                (0..d).map(|_| self.rng.next_laplace(0.0, b) as f32).collect()
            }
            2 => {
                let s = self.f64_in(1e-4, 5.0);
                (0..d).map(|_| self.rng.next_logistic(0.0, s) as f32).collect()
            }
            3 => {
                let a = self.f32_in(1e-4, 5.0);
                (0..d).map(|_| self.f32_in(-a, a)).collect()
            }
            _ => {
                let mut v = vec![0.0f32; d];
                let spikes = self.usize_in(1, (d / 10).max(1));
                for _ in 0..spikes {
                    let i = self.usize_in(0, d - 1);
                    v[i] = self.f32_in(-100.0, 100.0);
                }
                v
            }
        }
    }
}

/// Run `prop` over `cases` deterministic cases. Panics with the case
/// number and seed on first failure so the case is reproducible.
pub fn forall<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, mut prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let seed = 0x5eed_0000_u64 + case as u64;
        let mut g = Gen {
            rng: Pcg64::seed(seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall("true", |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'fail'")]
    fn forall_reports_failure() {
        forall("fail", |g| {
            if g.case == 3 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_in_range() {
        forall("ranges", |g| {
            let n = g.usize_in(5, 10);
            if !(5..=10).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let x = g.f64_in(-2.0, 3.0);
            if !(-2.0..3.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let d = g.usize_in(1, 64);
            let v = g.mixed_vec(d);
            if v.iter().any(|x| !x.is_finite()) {
                return Err("non-finite value".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn allclose() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
    }
}
