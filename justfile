# Task runner for the sparkv reproduction. Mirrors .github/workflows/rust.yml.

# Tier-1 verify: release build + quiet test run.
test:
    cd rust && cargo build --release && cargo test -q

# The lint CI job, locally: formatting + clippy with warnings denied.
lint:
    cd rust && cargo fmt --check && cargo clippy --all-targets -- -D warnings

# The nightly CI configuration, locally: 4× property-test cases for every
# testkit::forall invariant (serial/threaded equivalence, compressor
# contracts, error-feedback mass conservation).
test-heavy:
    cd rust && cargo build --release && SPARKV_PROPTEST_CASES=256 cargo test -q

# Fast bench pass (reduced dimension sweep).
bench-fast:
    cd rust && SPARKV_BENCH_FAST=1 cargo bench

# Full figure/table regeneration.
bench:
    cd rust && cargo bench
