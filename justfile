# Task runner for the sparkv reproduction. Mirrors .github/workflows/rust.yml.

# Tier-1 verify: release build + quiet test run.
test:
    cd rust && cargo build --release && cargo test -q

# The lint CI job, locally: formatting + clippy with warnings denied.
lint:
    cd rust && cargo fmt --check && cargo clippy --all-targets -- -D warnings

# The nightly CI configuration, locally: 4× property-test cases for every
# testkit::forall invariant (serial/threaded/pooled equivalence, compressor
# contracts, error-feedback mass conservation, the k-schedule property
# suite in tests/schedule_equivalence.rs, and the worker-pool suite in
# tests/pool_equivalence.rs).
test-heavy:
    cd rust && cargo build --release && SPARKV_PROPTEST_CASES=256 cargo test -q

# The bench-smoke CI job, locally: every bench target must still compile,
# and the scaling simulator must run end-to-end under a warmup k-schedule
# (exercises the scheduled sweep + density-trace plumbing).
bench-smoke:
    cd rust && cargo build --benches
    cd rust && cargo run --release --example scaling_sim -- \
        --k-schedule warmup:0.016..0.001,epochs=2 --sched-steps 24 --steps-per-epoch 6

# The pool axis of bench-smoke: the same scheduled sweep driven through
# the persistent worker-pool runtime, plus the real measured
# spawn-vs-dispatch comparison the --parallelism flag enables.
pool-smoke:
    cd rust && cargo run --release --example scaling_sim -- \
        --k-schedule warmup:0.016..0.001,epochs=2 --sched-steps 24 --steps-per-epoch 6 \
        --parallelism pool:4

# The gtopk-smoke leg of bench-smoke: the tree-sparse exchange end to
# end — a short *real* gTop-k training run over the recursive-halving
# tree (bit-identical to the dense-ring path by construction), then the
# netsim ring-vs-tree crossover sweep the cost model prices the mode
# switch with.
gtopk-smoke:
    cd rust && cargo run --release -- train --op topk --global-topk true \
        --exchange tree-sparse --workers 4 --steps 6
    cd rust && cargo run --release --example scaling_sim -- \
        --exchange tree-sparse --k-ratio 0.001

# The ring-smoke leg of bench-smoke: the pooled persistent-ring runtime
# end to end — a short *real* `pool:4` training run whose collectives
# execute on the pool's long-lived ring threads (dense ring, then the
# bucketed tree-sparse pipeline; both bit-identical to serial by
# construction), then the hierarchical topology sweep pricing flat vs
# two-level schedules on an oversubscribed fabric.
ring-smoke:
    cd rust && cargo run --release -- train --op topk --workers 4 --steps 6 \
        --parallelism pool:4
    cd rust && cargo run --release -- train --op topk --global-topk true \
        --exchange tree-sparse --workers 4 --steps 6 \
        --parallelism pool:4 --buckets bytes:1024
    cd rust && cargo run --release --example scaling_sim -- \
        --topology oversub:4 --sweep-hierarchical

# The select-smoke leg of bench-smoke: the warm-threshold selection
# engine end to end — the warm-vs-exact selection bench in fast mode
# (writes BENCH_select.json at the repo root with speedups + per-schedule
# warm-hit rates), then a short *real* `--select warm:0.25` training run
# on both bucket paths (bit-identical to exact for Top_k by
# construction; tests/select_equivalence.rs locks it).
select-smoke:
    cd rust && SPARKV_BENCH_FAST=1 cargo bench --bench select_speed
    cd rust && cargo run --release -- train --op topk --select warm:0.25 \
        --workers 4 --steps 6
    cd rust && cargo run --release -- train --op gaussiank --select warm:0.25 \
        --workers 4 --steps 6 --buckets bytes:1024

# The wire-smoke leg of bench-smoke: the bitpacked wire codec end to end
# — the codec bench in fast mode (writes BENCH_wire.json at the repo root
# with bytes/element, reduction vs raw, and round-trip GB/s for both
# payload families), then a short *real* `--wire packed` training run on
# both bucket paths (bit-identical to raw by construction;
# tests/wire_equivalence.rs locks it) and a `--wire packed+f16` run with
# the quantization residual folded into error feedback.
wire-smoke:
    cd rust && SPARKV_BENCH_FAST=1 cargo bench --bench wire_speed
    cd rust && cargo run --release -- train --op topk --wire packed \
        --workers 4 --steps 6
    cd rust && cargo run --release -- train --op topk --wire packed \
        --workers 4 --steps 6 --buckets bytes:1024
    cd rust && cargo run --release -- train --op topk --wire packed+f16 \
        --workers 4 --steps 6

# The trace-smoke leg of bench-smoke: the span tracer end to end — the
# overhead bench in fast mode (writes BENCH_trace.json at the repo root;
# ≤1% span-tracing overhead on the serial acceptance rows), traced
# threads:4 and pool:4 training runs writing Perfetto JSON at the repo
# root (the bucketed pool trace is the one that shows collective/
# selection overlap in ui.perfetto.dev), `sparkv report` folding each
# trace into the measured-vs-predicted drift table, and the
# malformed-trace guard (report must exit non-zero on garbage).
trace-smoke:
    cd rust && SPARKV_BENCH_FAST=1 cargo bench --bench trace_overhead
    cd rust && cargo run --release -- train --op topk --workers 4 --steps 8 \
        --parallelism threads:4 --trace spans:../TRACE_threads.json
    cd rust && cargo run --release -- train --op topk --workers 4 --steps 8 \
        --parallelism pool:4 --buckets bytes:1024 \
        --trace spans:../TRACE_pool.json
    cd rust && cargo run --release -- report ../TRACE_threads.json
    cd rust && cargo run --release -- report ../TRACE_pool.json
    cd rust && printf '{"broken": true}' > ../TRACE_broken.json && \
        if cargo run --release -- report ../TRACE_broken.json; then \
            echo "report accepted a malformed trace"; exit 1; fi

# The tune-smoke CI job, locally: the closed-loop autotuner end to end on
# a tiny grid (2 candidates, 3 measured calibration probe steps, 3
# virtual steps/epoch), then a real training replay of the plan it wrote
# — compiles and runs the whole tune → plan → `train --plan` loop.
tune-smoke:
    cd rust && cargo run --release -- tune --smoke --out results/tuned_plan_smoke.json
    cd rust && cargo run --release -- train --plan results/tuned_plan_smoke.json \
        --steps 6 --workers 4

# Fast bench pass (reduced dimension sweep).
bench-fast:
    cd rust && SPARKV_BENCH_FAST=1 cargo bench

# Full figure/table regeneration.
bench:
    cd rust && cargo bench
